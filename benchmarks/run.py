"""Benchmark harness: one module per paper table/figure + the roofline
report. Prints ``name,us_per_call,derived`` CSV lines (detail lines are
'#'-prefixed).

``--smoke`` skips the modeled tables and instead exercises every kernel in
the registry at tiny shapes with planner-sized pipes (interpret mode), so
the perf plumbing — registry enumeration, auto planning, the StreamProgram
compile path — cannot silently rot even where full benches are too slow.
It also writes ``BENCH_smoke.json`` (override with ``--json``): per-kernel
wall time, max error, and the modeled FF-vs-baseline speedup + planned
(depth, streams) at the registry bench shape point, so CI tracks the perf
trajectory run over run.

``--autotune`` runs the measured autotuner over every registry kernel
(``PipePolicy(mode="autotune")`` at the smoke shapes): the (tile, depth,
streams) space is searched empirically, tuned plans are persisted to the
plan cache (``~/.cache/repro/plans.json`` — CI restores it across runs, so
a warm cache skips re-measuring), and ``BENCH_autotune.json`` records the
measured tuned-vs-analytic comparison per kernel. ``--budget-s`` bounds
the total tuning wall time. Composes with ``--smoke``.

``--graph`` exercises every registered multi-kernel StreamGraph
(``repro.core.graph``) three ways — fused (compile_graph's per-edge
decision), staged (HBM handoffs forced), and unfused (separate repro.ops
calls) — checks all three against the XLA oracle, and writes
``BENCH_graph.json``: wall ms per lowering, per-edge fused/staged
decisions with rationales, and the modeled HBM bytes saved + estimate
``skipped`` lines (fusion rejections observable without rerunning).
Composes with the other modes.

``--sharded`` forces an 8-device host platform (``XLA_FLAGS`` set before
jax imports), builds a 1-D data mesh, and runs every registry kernel that
declares ``shard_dims`` under ``shard_map`` two ways: **local-planned**
(the default mesh-aware path — each shard plans against its local word
schedule with topology-keyed caches) and **global-planned** (depth/streams
pinned to the plan the *global* workload would get — the pre-mesh
behaviour every sharded path used to inherit). Both are parity-checked
against the unsharded op and the XLA oracle, timed interleaved, and
written to ``BENCH_sharded.json``. Composes with the other modes.

``--plans`` runs the fleet plan-service round trip (``repro.plans``):
records a serve-smoke traffic profile via ``--record-profile``, sweeps it
offline (``sweep_profile``, bounded by ``--budget-s``) into a versioned
PlanDB, checks that merging a foreign-fingerprint DB preserves both
namespaces bitwise, then replays the identical trace in a simulated fresh
process (cleared caches, swept DB only) and gates the plan-cache hit rate
at >= 0.9. Writes ``BENCH_plans.json`` (hit rate, cold-start sweep /
prewarm / replay seconds) plus the swept ``PLANDB_swept.json`` artifact
CI caches keyed by the plan-format version. ``--smoke`` shrinks the
trace and is consumed, like ``--serve``.

``--chaos`` runs the fault-injection suite (``repro.runtime.chaos``):
SIGKILL + cold-cache restart (bitwise resume, plan snapshot pre-warmed,
zero re-measurements), boundary-coincident SIGTERM drain (exactly one
save), pod eviction (stale-mesh plans dropped, PlanDB serves the new
topology), and an injected straggler (MAD detection -> rebalance ->
shrunk-shard re-plan). Writes ``BENCH_chaos.json`` (per-scenario ok +
recovery seconds + plan-stat breakdowns) and exits non-zero if any
scenario fails — the CI resilience gate. ``--smoke`` shrinks step counts
and is consumed, like ``--serve``.

``--telemetry`` runs the bandwidth-utilization suite (``repro.obs``):
every registry kernel and every registered graph is timed with live
tracing on (spans -> ``BENCH_trace.jsonl``), and the modeled byte counts
are joined with the measured wall into achieved GB/s + roofline
utilization per kernel and per graph edge (``BENCH_telemetry.json``).
Three gates make it a CI check on the telemetry stack itself: every
utilization must land in (0, 1], the span layer must cost < 3% wall
overhead (interleaved disabled-vs-enabled timing), and the serve
schedulers' live latency histograms must match the post-hoc bench
percentiles within 10%. ``--smoke`` is consumed, like ``--serve``.

``--out-dir`` routes every bare artifact filename above (the
``--*-json`` defaults, ``--plans-db-out``, ``--trace-jsonl``) into one
directory — the single knob CI uses to collect artifacts; explicit
paths pass through untouched."""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
import traceback


def smoke(json_path: str = "BENCH_smoke.json") -> None:
    import jax.numpy as jnp

    from repro.core import (TPU_V5E, estimate_baseline, estimate_feedforward,
                            plan_cache_info, planned_pipe)
    from repro.kernels.registry import all_kernels, run_smoke

    results = []
    failures = []
    print("# smoke: every registered kernel, tiny shapes, depth/streams=auto")
    for spec in all_kernels():
        t0 = time.time()
        try:
            _, _, err = run_smoke(spec)
            ok = err <= spec.tol
        except Exception:   # noqa: BLE001 — report all kernels
            traceback.print_exc()
            ok, err = False, float("nan")
        dt_ms = (time.time() - t0) * 1e3
        row = {
            "kernel": spec.name,
            "alias": spec.alias,
            "ok": bool(ok),
            # None (JSON null), not NaN: bare NaN tokens break RFC-8259
            # parsers of the CI-uploaded artifact
            "max_abs_err": float(err) if math.isfinite(err) else None,
            "tol": spec.tol,
            "smoke_wall_ms": round(dt_ms, 1),
            "model_ok": True,
        }
        try:
            # modeled trajectory numbers at the bench shape point
            kw = dict(spec.bench_kwargs)
            dtype = kw.get("dtype", jnp.float32)
            w, tile = spec.workload(**kw)
            plan = planned_pipe(spec.name, w, tile, dtype, TPU_V5E)
            base = estimate_baseline(w, TPU_V5E)
            ff = estimate_feedforward(w, TPU_V5E, plan.pipe)
            row.update({
                "est_speedup": round(base.total_s / ff.total_s, 3),
                "est_us_per_call": round(ff.total_s * 1e6, 1),
                "plan": {"depth": plan.pipe.depth,
                         "streams": plan.pipe.streams,
                         "skipped": list(plan.skipped)},
                "bottleneck": ff.bottleneck,
            })
        except Exception:   # noqa: BLE001 — still report the other kernels
            traceback.print_exc()
            row["model_ok"] = False    # modeling bug, not a kernel failure
            failures.append(f"{spec.name} (modeled metrics)")
        results.append(row)
        status = "ok" if ok else "FAIL"
        print(f"smoke/{spec.name},{dt_ms:.0f},err={err:.1e}_{status}")
        if not ok:
            failures.append(spec.name)
    cache = plan_cache_info()
    print(f"# plan cache: {cache}")
    if json_path:
        payload = {
            "suite": "smoke",
            "kernels": results,
            "plan_cache": {"hits": cache.hits, "misses": cache.misses},
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_path}")
    if failures:
        print(f"\nFAILED smoke kernels: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("smoke ok")


def autotune_bench(json_path: str = "BENCH_autotune.json",
                   budget_s: float | None = None) -> None:
    """Tune every registry kernel with the measured autotuner and report
    tuned-vs-analytic per kernel. The analytic plan's configuration is
    always in the measured candidate set, so the tuned choice can only be
    at least as fast (within timing noise); a >5% regression is a harness
    bug and fails the run."""
    import jax
    import numpy as np

    from repro.core import PLAN_FORMAT_VERSION, PipePolicy
    from repro.core import autotune as at
    from repro.kernels.registry import all_kernels

    results = []
    failures = []
    specs = all_kernels()
    t_end = None if budget_s is None else time.monotonic() + budget_s
    print("# autotune: measured (tile, depth, streams) per registry kernel")
    print(f"# plan cache: {at.cache_path()} (format {PLAN_FORMAT_VERSION})")
    for i, spec in enumerate(specs):
        per_kernel = None
        if t_end is not None:
            # split what is left of the budget across the kernels left
            per_kernel = max((t_end - time.monotonic()) / (len(specs) - i),
                             1.0)
        t0 = time.time()
        try:
            with at.tuning_config(budget_s=per_kernel):
                args, kw = spec.make_inputs(jax.random.key(0))
                np.asarray(spec.op(*args, **kw,
                                   policy=PipePolicy(mode="autotune")))
            rec = at.last_record(spec.name)
            if rec is None:
                raise RuntimeError("no tuned plan was recorded")
        except Exception:   # noqa: BLE001 — report all kernels
            traceback.print_exc()
            failures.append(spec.name)
            results.append({"kernel": spec.name, "ok": False})
            print(f"autotune/{spec.name},nan,FAIL")
            continue
        wall_ms = (time.time() - t0) * 1e3
        tuned_ms = rec["measured_s"] * 1e3
        ana = rec["analytic"]
        ana_ms = (ana.get("measured_s") or float("nan")) * 1e3
        speedup = ana_ms / tuned_ms if tuned_ms else float("nan")
        # argmin over a set containing the analytic config: tuned can only
        # regress through measurement noise, so >5% slower = harness bug
        ok = not math.isfinite(speedup) or speedup >= 0.95
        results.append({
            "kernel": spec.name,
            "alias": spec.alias,
            "ok": bool(ok),
            "source": rec["source"],
            "tuned": {"tile": rec["tile_kwargs"], "depth": rec["depth"],
                      "streams": rec["streams"],
                      "measured_ms": round(tuned_ms, 3)},
            "analytic": {"depth": ana["depth"], "streams": ana["streams"],
                         "predicted_ms": round(ana["predicted_s"] * 1e3, 4),
                         "measured_ms": (round(ana_ms, 3)
                                         if math.isfinite(ana_ms) else None)},
            "speedup_vs_analytic": (round(speedup, 3)
                                    if math.isfinite(speedup) else None),
            "candidates_measured": sum(
                1 for c in rec["candidates"]
                if c.get("measured_s") is not None),
            "candidates_considered": len(rec["candidates"]),
            "skipped": list(rec.get("skipped", ()))[:10],
            "tune_wall_ms": round(wall_ms, 1),
        })
        print(f"autotune/{spec.name},{tuned_ms * 1e3:.0f},"
              f"speedup_vs_analytic={speedup:.2f}_{rec['source']}")
        if not ok:
            failures.append(f"{spec.name} (tuned slower than analytic)")
    if json_path:
        payload = {
            "suite": "autotune",
            "plan_format": PLAN_FORMAT_VERSION,
            "kernels": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_path}")
    if failures:
        print(f"\nFAILED autotune kernels: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("autotune ok")


def _interleaved_ms(variants, warmup: int = 2, iters: int = 5):
    """Median wall ms per variant, sampled round-robin (one timed call of
    each variant per round). Interpret-mode wall times drift with machine
    load at the 10%+ level over seconds; interleaving makes every variant
    see the same drift, so the *ordering* is trustworthy even when the
    absolute numbers wobble."""
    import statistics

    import jax

    samples = {name: [] for name, _ in variants}
    for _ in range(max(warmup, 0)):
        for _, fn in variants:
            jax.block_until_ready(fn())
    for _ in range(max(iters, 1)):
        for name, fn in variants:
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            samples[name].append((time.perf_counter() - t0) * 1e3)
    return {name: float(statistics.median(ts))
            for name, ts in samples.items()}


def graph_bench(json_path: str = "BENCH_graph.json",
                iters: int = 5) -> None:
    """Bench every registered StreamGraph: fused vs staged vs unfused.

    The fused lowering removes the intermediate's HBM round trip (and, in
    interpret mode, a whole pallas_call dispatch), so the expected ordering
    is fused <= staged <= unfused wall time; the three lowerings are timed
    interleaved (round-robin) so load drift cannot fake an inversion, and
    a fused run slower than staged beyond interleaved noise (>25%) fails
    the bench — the per-edge decision should never have fused that graph.
    Numerics of all three lowerings are checked against the XLA oracle."""
    import jax
    import numpy as np

    from repro.kernels.registry import all_graphs, run_graph_smoke

    results = []
    failures = []
    print("# graph: fused vs staged vs unfused per registered StreamGraph")
    for spec in all_graphs():
        t0 = time.time()
        try:
            args = spec.make_inputs(jax.random.key(0))
            ref = np.float32(spec.ref(*args))
            _, _, err_f, fused = run_graph_smoke(spec)
            _, _, err_s, staged = run_graph_smoke(spec, prefer="staged")
            err_u = float(np.max(np.abs(
                np.float32(spec.unfused(*args)) - ref)))
            ok = max(err_f, err_s, err_u) <= spec.tol
            wall = _interleaved_ms(
                [("fused", lambda: fused(*args)),
                 ("staged", lambda: staged(*args)),
                 ("unfused", lambda: spec.unfused(*args))],
                warmup=2, iters=iters)
            fused_ms = wall["fused"]
            staged_ms = wall["staged"]
            unfused_ms = wall["unfused"]
        except Exception:   # noqa: BLE001 — report all graphs
            traceback.print_exc()
            failures.append(spec.name)
            results.append({"graph": spec.name, "ok": False})
            print(f"graph/{spec.name},nan,FAIL")
            continue
        if fused_ms > staged_ms * 1.25:
            ok = False
            failures.append(f"{spec.name} (fused slower than staged: "
                            f"{fused_ms:.1f}ms vs {staged_ms:.1f}ms)")
        est = fused.plan.estimate
        results.append({
            "graph": spec.name,
            "ok": bool(ok),
            "max_abs_err": {"fused": err_f, "staged": err_s,
                            "unfused": err_u},
            "tol": spec.tol,
            "wall_ms": {"fused": round(fused_ms, 3),
                        "staged": round(staged_ms, 3),
                        "unfused": round(unfused_ms, 3)},
            "edges": [{
                "edge": ep.edge.label,
                "mode": ep.mode,
                "hbm_bytes_saved": ep.hbm_bytes_saved,
                "rationale": ep.rationale,
            } for ep in fused.plan.edges],
            "units": [u.kind for u in fused.units],
            "sizing": {k: list(v) for k, v in fused.plan.sizing.items()},
            "modeled": {
                "total_ms": round(est.total_s * 1e3, 6),
                "unfused_ms": round(est.unfused_s * 1e3, 6),
                "overlap_speedup": round(est.overlap_speedup, 3),
                "hbm_bytes_saved": est.hbm_bytes_saved,
                # estimate_graph's per-edge rejection lines, surfaced the
                # same way Plan.skipped is in the smoke JSON
                "skipped": list(est.skipped),
            },
            "bench_wall_ms": round((time.time() - t0) * 1e3, 1),
        })
        status = "ok" if ok else "FAIL"
        print(f"graph/{spec.name},{fused_ms * 1e3:.0f},"
              f"fused={fused_ms:.1f}ms_staged={staged_ms:.1f}ms_"
              f"unfused={unfused_ms:.1f}ms_{status}")
        if not ok and spec.name not in [f.split(" ")[0] for f in failures]:
            failures.append(spec.name)
    if json_path:
        payload = {"suite": "graph", "graphs": results}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_path}")
    if failures:
        print(f"\nFAILED graphs: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("graph ok")


def sharded_bench(json_path: str = "BENCH_sharded.json", n_dev: int = 8,
                  iters: int = 5) -> None:
    """Bench every shardable registry kernel on the forced host mesh:
    local-planned (mesh-aware) vs global-planned (pre-mesh sizing).

    The local plan sizes pipes for the per-shard word schedule the kernel
    actually streams inside ``shard_map``; the global plan is what the
    same call site inherited before the runtime was mesh-aware — the
    (depth, streams) of the *global* workload, pinned. Both lowerings are
    parity-checked (sharded == unsharded == oracle) and timed interleaved
    so load drift cannot fake an ordering."""
    import jax
    import numpy as np

    from repro.core import MeshSpec, TPU_V5E, PipePolicy, planned_pipe
    from repro.core.planner import last_plan
    from repro.kernels.registry import all_kernels, run_sharded_smoke, \
        shard_partition_specs, sharded_inputs
    from repro.runtime import sharding as shlib
    from repro.runtime.streams import shard_streams

    if len(jax.devices()) < n_dev:
        raise SystemExit(
            f"--sharded needs {n_dev} host devices; run through "
            f"benchmarks/run.py (it sets XLA_FLAGS before jax imports)")
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:n_dev]), ("data",))
    results = []
    failures = []
    print(f"# sharded: registry kernels under shard_map on a "
          f"{n_dev}-device data mesh (local- vs global-planned)")
    with shlib.use_sharding(mesh):
        for spec in all_kernels():
            t0 = time.time()
            if spec.shard_dims is None:
                results.append({"kernel": spec.name, "ok": True,
                                "skipped": "no shard_dims declared"})
                print(f"sharded/{spec.name},nan,skipped_no_shard_dims")
                continue
            try:
                # parity: sharded == unsharded == oracle (local-planned)
                _, un, _, err_un, err_ref = run_sharded_smoke(spec, mesh)
                ok = max(err_un, err_ref) <= max(spec.tol, 1e-6)

                args, kw = sharded_inputs(spec, n_dev)
                local_plan = last_plan(spec.name)
                # the pre-mesh sizing: plan at the *global* workload shapes
                dtype = kw.get("dtype", args[0].dtype)
                w_g, tile_g = _global_workload(spec, args, kw)
                g_plan = planned_pipe(f"{spec.name}/global", w_g, tile_g,
                                      dtype, TPU_V5E)
                # explicit per-call policies bypass the session policy the
                # shard_streams wrapper installs — tag the mesh directly
                mspec = MeshSpec.from_mesh(mesh)
                pol_local = PipePolicy(mesh=mspec)
                pol_global = PipePolicy(depth=g_plan.pipe.depth,
                                        streams=g_plan.pipe.streams,
                                        mesh=mspec)

                in_specs, out_spec = shard_partition_specs(spec, args,
                                                           un.ndim)
                f_local = shard_streams(
                    lambda *a: spec.op(*a, **kw, policy=pol_local),
                    in_specs=in_specs, out_specs=out_spec, mesh=mesh)
                f_global = shard_streams(
                    lambda *a: spec.op(*a, **kw, policy=pol_global),
                    in_specs=in_specs, out_specs=out_spec, mesh=mesh)
                # the global-planned lowering is parity-checked too — a
                # pinned depth/streams the local shard cannot honor must
                # fail loudly, not ship as a silently wrong A/B baseline
                err_global = float(np.max(np.abs(
                    np.float32(f_global(*args)) - un)))
                ok = ok and err_global <= max(spec.tol, 1e-6)
                wall = _interleaved_ms(
                    [("local", lambda: f_local(*args)),
                     ("global", lambda: f_global(*args))],
                    warmup=1, iters=iters)
            except Exception:   # noqa: BLE001 — report all kernels
                traceback.print_exc()
                failures.append(spec.name)
                results.append({"kernel": spec.name, "ok": False})
                print(f"sharded/{spec.name},nan,FAIL")
                continue
            results.append({
                "kernel": spec.name,
                "alias": spec.alias,
                "ok": bool(ok),
                "devices": n_dev,
                "mesh": f"data{n_dev}",
                "max_abs_err": {"vs_unsharded": err_un, "vs_ref": err_ref,
                                "global_planned_vs_unsharded": err_global},
                "tol": spec.tol,
                "wall_ms": {"local_planned": round(wall["local"], 3),
                            "global_planned": round(wall["global"], 3)},
                "plan": {
                    "local": {"depth": local_plan.pipe.depth,
                              "streams": local_plan.pipe.streams,
                              "n_words": local_plan.workload.n_words,
                              "mesh": local_plan.mesh.token},
                    "global": {"depth": g_plan.pipe.depth,
                               "streams": g_plan.pipe.streams,
                               "n_words": w_g.n_words},
                },
                "bench_wall_ms": round((time.time() - t0) * 1e3, 1),
            })
            status = "ok" if ok else "FAIL"
            print(f"sharded/{spec.name},{wall['local'] * 1e3:.0f},"
                  f"local={wall['local']:.1f}ms_global={wall['global']:.1f}"
                  f"ms_{status}")
            if not ok:
                failures.append(spec.name)
    if json_path:
        payload = {"suite": "sharded", "devices": n_dev, "kernels": results}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_path}")
    if failures:
        print(f"\nFAILED sharded kernels: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("sharded ok")


def serve_bench_mode(json_path: str = "BENCH_serve.json",
                     smoke: bool = True) -> None:
    """Continuous-batching serving bench: paged-KV continuous batching vs.
    the padded lockstep baseline over the same Poisson trace (see
    ``repro.launch.serve``). Writes ``BENCH_serve.json`` with per-scheduler
    p50/p99 per-token latency, tokens/s, and KV utilization, plus the
    paged-vs-contiguous bitwise parity probe. Both sizes run the smoke
    model config (CPU interpret container); ``smoke`` only shrinks the
    trace."""
    from repro.launch import serve as serve_lib

    ap = argparse.ArgumentParser()
    serve_lib.add_serve_args(ap)
    if smoke:
        argv = ["--smoke", "--requests", "8", "--slots", "2",
                "--prompt-len", "16", "--max-new", "8", "--rate", "20"]
    else:
        argv = ["--smoke", "--requests", "24", "--slots", "4",
                "--prompt-len", "48", "--max-new", "24", "--rate", "10"]
    args = ap.parse_args(argv)
    print("# serve: paged continuous batching vs padded lockstep "
          f"(requests={args.requests}, slots={args.slots}, "
          f"page={args.page})")
    result = serve_lib.serve_bench(args)
    ls, pg = result["lockstep"], result["paged"]
    for name, m in (("lockstep", ls), ("paged", pg)):
        print(f"# {name:9s} {m['tokens']} tokens {m['tokens_per_s']:.2f} "
              f"tok/s p99 {m['p99_ms']:.0f} ms kv_util {m['kv_util']:.2f}")
    print(f"serve,speedup_tokens_per_s,{result['speedup_tokens_per_s']:.3f}")
    print(f"serve,p99_ratio,{result['p99_ratio']:.3f}")
    print(f"serve,bitwise_max_abs_diff,{result['bitwise_max_abs_diff']:.1e}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_path}")
    if not result["bitwise_identical"]:
        print("\nFAILED: paged decode is not bitwise-identical to the "
              "contiguous path", file=sys.stderr)
        raise SystemExit(1)
    if result["speedup_tokens_per_s"] <= 1.0 or (
            result["p99_ratio"] is not None and result["p99_ratio"] <= 1.0):
        print("\nFAILED: paged continuous batching did not beat the "
              "lockstep baseline (tokens/s and p99)", file=sys.stderr)
        raise SystemExit(1)
    print("serve ok")


def plans_bench(json_path: str = "BENCH_plans.json", smoke: bool = True,
                budget_s: float = None,
                db_out: str = "PLANDB_swept.json") -> None:
    """Plan-service round trip (``repro.plans``): record a serve-smoke
    traffic profile, sweep it offline into a PlanDB under a budget, then
    replay the same trace in a simulated fresh process (cleared in-memory
    caches, empty host cache) with only the swept DB and measure the
    plan-cache hit rate. Writes ``BENCH_plans.json`` with the hit rate
    (gated >= 0.9), cold-start tuning/prewarm times, and a namespace-
    bitwise merge check; the swept DB lands at ``db_out`` so CI can cache
    it across runs keyed by PLAN_FORMAT_VERSION."""
    import shutil
    import tempfile
    import warnings

    from repro.core import autotune
    from repro.launch import serve as serve_lib
    from repro.plans import PlanDB, TrafficProfile, sweep_profile
    from repro.plans import plandb as plandb_lib

    tmp = tempfile.mkdtemp(prefix="repro-plans-")
    profile_path = os.path.join(tmp, "traffic.json")
    db_path = os.path.join(tmp, "plans_db.json")
    if smoke:
        base = ["--smoke", "--requests", "6", "--slots", "2",
                "--prompt-len", "12", "--max-new", "6", "--rate", "20"]
        budget_s = 600.0 if budget_s is None else budget_s
    else:
        base = ["--smoke", "--requests", "16", "--slots", "4",
                "--prompt-len", "48", "--max-new", "16", "--rate", "10"]
    base += ["--policy-mode", "autotune"]
    ap = argparse.ArgumentParser()
    serve_lib.add_serve_args(ap)

    def run_serve(extra, host_cache):
        args = ap.parse_args(base + extra)
        with autotune.tuning_config(cache_path=host_cache), \
                warnings.catch_warnings():
            # in-jit autotune call sites warn per (op, workload) and fall
            # back analytic — exactly the misses this bench measures
            warnings.simplefilter("ignore", RuntimeWarning)
            t0 = time.perf_counter()
            serve_lib.serve_bench(args)
            return time.perf_counter() - t0

    # 1. record: the serve-smoke trace with an empty cache and no DB —
    #    every measured-policy resolution is a cold miss, and the recorder
    #    captures the exact call-site traffic
    print("# plans: recording serve-smoke traffic profile")
    autotune.tuned_cache_clear()
    autotune.plan_stats_clear()
    record_s = run_serve(["--record-profile", profile_path],
                         os.path.join(tmp, "record_host.json"))
    cold_stats = autotune.plan_stats_snapshot()

    # 2. sweep: tune offline from the recorded profile under the budget,
    #    highest observed-frequency x modeled-cost bucket first
    profile = TrafficProfile.load(profile_path)
    print(f"# plans: sweeping {len(profile)} buckets "
          f"({profile.total_count} observations, budget {budget_s}s)")
    autotune.tuned_cache_clear()
    # top_k=2 keeps the smoke sweep to (analytic reference + best
    # predicted) per bucket: interpret-mode compiles dominate, coverage
    # of all buckets matters more here than search depth
    sweep = sweep_profile(profile, budget_s=budget_s,
                          scratch_cache=os.path.join(tmp, "scratch.json"),
                          warmup=0, iters=1, top_k=2 if smoke else None)
    sweep.db.save(db_path)
    for line in sweep.skipped:
        print(f"#   sweep skipped: {line}")

    # 3. merge check: a DB tuned on a different hw fingerprint merges in
    #    without rewriting a byte of either namespace
    foreign = PlanDB()
    for key, rec in sweep.db.records(sweep.namespace).items():
        foreign.put("tpu.fake-v5e", key, rec, tuned_at=0.0)
    merged = PlanDB.load(db_path)
    report = merged.merge(foreign)
    merge_ok = (
        json.dumps(merged.records(sweep.namespace), sort_keys=True)
        == json.dumps(sweep.db.records(sweep.namespace), sort_keys=True)
        and json.dumps(merged.records("tpu.fake-v5e"), sort_keys=True)
        == json.dumps(foreign.records("tpu.fake-v5e"), sort_keys=True)
        and not report.conflicts)

    # 4. replay: fresh-process simulation — in-memory caches cleared, a
    #    fresh (empty) host cache, only the swept DB in the chain
    print("# plans: replaying the trace against the swept PlanDB")
    autotune.tuned_cache_clear()
    plandb_lib.clear_cache()
    autotune.plan_stats_clear()
    prewarm = plandb_lib.prewarm(db_path)
    replay_s = run_serve(["--plan-db", db_path],
                         os.path.join(tmp, "cold_host.json"))
    warm_stats = autotune.plan_stats_snapshot()

    payload = {
        "suite": "plans",
        "smoke": smoke,
        "profile": {"buckets": len(profile),
                    "observations": profile.total_count},
        "sweep": sweep.to_payload(),
        "hit_rate": warm_stats["hit_rate"],
        "stats_cold": cold_stats,
        "stats_warm": warm_stats,
        "cold_start": {
            # what a fresh host pays without the artifact (full offline
            # sweep) vs. with it (parse + dict lookups)
            "record_s": record_s,
            "sweep_s": sweep.wall_s,
            "prewarm_s": prewarm["prewarm_s"],
            "replay_s": replay_s,
        },
        "prewarm": prewarm,
        "merge_namespaces_bitwise": merge_ok,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_path}")
    if db_out:
        shutil.copyfile(db_path, db_out)
        print(f"# wrote {db_out}")
    hr = warm_stats["hit_rate"]
    print(f"plans,hit_rate,{hr if hr is not None else 'n/a'}")
    print(f"plans,sweep_s,{sweep.wall_s:.2f}")
    print(f"plans,prewarm_s,{prewarm['prewarm_s']:.4f}")
    if not merge_ok:
        print("\nFAILED: PlanDB merge did not preserve both namespaces "
              "bitwise", file=sys.stderr)
        raise SystemExit(1)
    if hr is None or hr < 0.9:
        print(f"\nFAILED: plan-cache hit rate {hr} < 0.9 on the fresh-"
              f"process replay (misses: "
              f"{warm_stats.get('measured', 0)} measured, "
              f"{warm_stats.get('analytic-fallback', 0)} fallback)",
              file=sys.stderr)
        raise SystemExit(1)
    print("plans ok")


def _global_workload(spec, args, kw):
    """The Workload of the *global* (unsharded) operand shapes — what the
    planner saw before the runtime became mesh-aware."""
    builders = {
        "ff_matmul": lambda: spec.workload(
            args[0].shape[0], args[1].shape[1], args[0].shape[1],
            kw.get("block", (128, 128, 128)), args[0].dtype),
        "ff_attention": lambda: spec.workload(
            args[0].shape[0], args[0].shape[1], args[0].shape[2],
            causal=kw.get("causal", True),
            block_q=kw.get("block_q", 128), block_kv=kw.get("block_kv", 128),
            dtype=args[0].dtype),
    }
    if spec.name in builders:
        return builders[spec.name]()
    # generic fallback: synthesize from the program declaration scaled to
    # the sharded operand count (words scale with the data-parallel rows)
    from repro.core import program_workload
    import dataclasses as _dc
    prog = spec.program(depth=2, streams=1)
    w = program_workload(prog)
    return _dc.replace(w, n_words=w.n_words * _shard_factor(spec, args)), \
        tuple(prog.streams[0].spec.tile)


def _shard_factor(spec, args):
    """How many per-shard smoke inputs were concatenated into ``args``."""
    import jax

    one, _ = spec.make_inputs(jax.random.key(0))
    for a, ref, dim in zip(args, one, spec.shard_dims):
        if dim is not None:
            return max(a.shape[dim] // ref.shape[dim], 1)
    return 1


def chaos_bench(json_path: str = "BENCH_chaos.json",
                smoke: bool = True) -> None:
    """Fault-injection suite: every scenario must hold its invariant.

    Orchestration only — the workers are subprocesses, so this mode stays
    jax-free in the parent and each restart legitimately starts with a
    cold plan cache."""
    from repro.runtime import chaos

    result = chaos.run_scenarios(smoke=smoke)
    for name, sc in sorted(result["scenarios"].items()):
        ok = "ok" if sc.get("ok") else "FAIL"
        extras = []
        for key in ("recovery_s", "bitwise_identical", "save_count",
                    "post_remesh_source", "share_after"):
            if key in sc:
                v = sc[key]
                extras.append(f"{key}={v:.2f}" if isinstance(v, float)
                              else f"{key}={v}")
        print(f"# chaos {name}: {ok} " + " ".join(extras))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_path}")
    if not result["ok"]:
        failed = [n for n, s in result["scenarios"].items()
                  if not s.get("ok")]
        print(f"chaos scenarios FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)
    print(f"chaos ok ({result['wall_s']:.1f}s)")


def telemetry_bench(json_path: str = "BENCH_telemetry.json",
                    trace_path: str = "BENCH_trace.jsonl",
                    smoke: bool = True, iters: int = 5) -> None:
    """Bandwidth-utilization telemetry: join modeled byte counts with
    measured wall time into achieved GB/s + roofline utilization per
    kernel and per graph edge, under live tracing (spans appended to
    ``trace_path`` as JSONL — plan-source tags included). Gates the
    telemetry stack itself three ways: every utilization must land in
    (0, 1]; the span layer must cost < 3% wall overhead on an
    instrumented workload (interleaved disabled-vs-enabled timing); and
    the serve scheduler's live latency histogram must agree with the
    post-hoc bench percentiles within 10%. Writes ``BENCH_telemetry
    .json``; any gate failure exits non-zero. ``--smoke`` is consumed,
    like ``--serve``."""
    import jax
    import numpy as np   # noqa: F401 — jax platform init order

    from repro import obs
    from repro.core import TPU_V5E, PipePolicy, planned_pipe
    from repro.core.planner import last_plan
    from repro.kernels.registry import (all_graphs, all_kernels,
                                        run_graph_smoke)
    from repro.launch import serve as serve_lib

    hw = TPU_V5E
    failures = []
    if trace_path and os.path.exists(trace_path):
        os.remove(trace_path)    # append-mode sink: drop stale records
    prev_obs = obs.enable(trace_path or None)
    obs.metrics_clear("serve_token_latency_seconds")
    try:
        print(f"# telemetry: achieved GB/s vs roofline "
              f"({hw.hbm_bw / 1e9:.0f} GB/s), spans -> "
              f"{trace_path or '<memory ring>'}")
        policy = PipePolicy(mode="ff", interpret=True)

        def check_util(label, util):
            if not (0.0 < util["utilization"] <= 1.0):
                failures.append(f"{label} utilization "
                                f"{util['utilization']} outside (0, 1]")

        # 1. per-kernel: the workload the planner sized the pipe for, at
        #    the smoke shapes actually executed, over the measured wall
        kernels = {}
        first_op = None
        for spec in all_kernels():
            try:
                args, kw = spec.make_inputs(jax.random.key(0))
                fn = (lambda a=args, k=kw, s=spec:
                      s.op(*a, **k, policy=policy))
                jax.block_until_ready(fn())   # compile + plan
                plan = last_plan(spec.name)
                wall_ms = _interleaved_ms([("op", fn)], warmup=1,
                                          iters=iters)["op"]
                util = obs.kernel_utilization(plan.workload, hw,
                                              wall_ms / 1e3)
            except Exception:   # noqa: BLE001 — report all kernels
                traceback.print_exc()
                failures.append(spec.name)
                kernels[spec.name] = {"ok": False}
                print(f"telemetry/{spec.name},nan,FAIL")
                continue
            check_util(spec.name, util)
            if first_op is None:
                first_op = (spec, fn)
            util["plan"] = {"depth": plan.pipe.depth,
                            "streams": plan.pipe.streams}
            util["wall_ms"] = round(wall_ms, 3)
            kernels[spec.name] = util
            print(f"telemetry/{spec.name},{wall_ms * 1e3:.0f},"
                  f"achieved={util['achieved_gb_s']:.3f}GB/s_"
                  f"util={util['utilization']:.2e}")

        # 2. per-graph: the compiled fused graph's estimate carries
        #    post-fusion per-stage traffic; the measured wall is
        #    attributed by modeled-time share, then joined per edge
        graphs = {}
        for spec in all_graphs():
            try:
                args = spec.make_inputs(jax.random.key(0))
                _, _, _, fused = run_graph_smoke(spec)
                wall_ms = _interleaved_ms(
                    [("fused", lambda: fused(*args))],
                    warmup=1, iters=iters)["fused"]
                util = obs.graph_utilization(fused.plan.estimate, hw,
                                             wall_ms / 1e3)
            except Exception:   # noqa: BLE001 — report all graphs
                traceback.print_exc()
                failures.append(spec.name)
                graphs[spec.name] = {"ok": False}
                print(f"telemetry/{spec.name},nan,FAIL")
                continue
            check_util(spec.name, util["graph"])
            for e in util["edges"]:
                check_util(f"{spec.name}:{e['edge']}", e)
            util["graph"]["wall_ms"] = round(wall_ms, 3)
            graphs[spec.name] = util
            edges = ",".join(f"{e['edge']}({e['mode']})"
                             for e in util["edges"])
            print(f"telemetry/{spec.name},{wall_ms * 1e3:.0f},"
                  f"achieved={util['graph']['achieved_gb_s']:.3f}GB/s_"
                  f"edges={edges}")

        # 3. overhead gate: the same instrumented workload (a cache-hit
        #    plan resolution — its span fires every call — plus real
        #    kernel work), timed interleaved with tracing off vs on. The
        #    span layer must stay under 3%.
        if first_op is None:
            print(f"\nFAILED telemetry: no kernel compiled "
                  f"({failures})", file=sys.stderr)
            raise SystemExit(1)
        import jax.numpy as jnp
        spec0, fn0 = first_op
        kw0 = dict(spec0.bench_kwargs)
        w0, tile0 = spec0.workload(**kw0)
        dtype0 = kw0.get("dtype", jnp.float32)

        def work():
            # one timed sample = several plan-resolution + kernel rounds:
            # long samples amortize scheduler jitter and the sink's
            # batched-flush bursts, so the per-sample noise floor sits
            # well under the 3% gate on a loaded machine
            for _ in range(4):
                planned_pipe(spec0.name, w0, tile0, dtype0, hw)
                for _ in range(3):
                    out = fn0()
            return out

        # steady-state cost only: the enable/disable transitions (which
        # close and lazily reopen the JSONL sink) happen OUTSIDE the
        # timed regions, and a throwaway span re-opens the sink before
        # each enabled sample — a traced session holds its file open, so
        # per-round reopen cost would be harness artifact, not overhead.
        # Each round times the two variants back to back (order swapped
        # every other round to cancel position bias). The gate statistic
        # is the lower quartile of the per-round *differences*: pairing
        # cancels the load drift both timings in a round share, and
        # scheduler noise is one-sided (spikes only ever add time) while
        # real span cost is present in every round — so a low quantile
        # rejects the spikes yet still detects genuine overhead (the
        # same reasoning behind timeit's documented min-of-runs).
        import statistics

        def timed_off():
            st = obs.disable()
            t0 = time.perf_counter()
            jax.block_until_ready(work())
            dt = time.perf_counter() - t0
            obs.restore(st)
            return dt

        def timed_on():
            with obs.span("overhead_probe"):
                pass                      # re-open the sink, untimed
            t0 = time.perf_counter()
            jax.block_until_ready(work())
            return time.perf_counter() - t0

        off_s, on_s, diffs = [], [], []
        for _ in range(2):
            jax.block_until_ready(work())
        for j in range(max(iters * 3, 16)):
            if j % 2:
                on = timed_on()
                off = timed_off()
            else:
                off = timed_off()
                on = timed_on()
            off_s.append(off)
            on_s.append(on)
            diffs.append(on - off)
        base = statistics.median(off_s)
        q25_diff = sorted(diffs)[len(diffs) // 4]
        wall = {"disabled": base * 1e3,
                "enabled": (base + q25_diff) * 1e3}
        overhead = q25_diff / base
        overhead_ok = overhead < 0.03
        if not overhead_ok:
            failures.append(f"tracing overhead {overhead:.1%} >= 3%")
        print(f"telemetry/overhead,{wall['enabled'] * 1e3:.0f},"
              f"frac={overhead:+.4f}_{'ok' if overhead_ok else 'FAIL'}")

        # 4. serve parity: the live histogram observed exactly the
        #    quantities _summarize computes post hoc, so live p50/p99
        #    must agree with the bench JSON within the histogram's
        #    bucket resolution (<< the 10% gate)
        ap = argparse.ArgumentParser()
        serve_lib.add_serve_args(ap)
        sargs = ap.parse_args(
            ["--smoke", "--requests", "8", "--slots", "2",
             "--prompt-len", "16", "--max-new", "8", "--rate", "20"])
        result = serve_lib.serve_bench(sargs)
        parity = {"ok": True}
        for sched in ("lockstep", "paged"):
            summ = obs.histogram("serve_token_latency_seconds",
                                 scheduler=sched).summary()
            row = {"samples": summ.get("count", 0)}
            for q in ("p50", "p99"):
                live_ms = summ.get(q, float("nan")) * 1e3
                post_ms = result[sched][f"{q}_ms"]
                rel = (abs(live_ms - post_ms) / post_ms
                       if post_ms else float("nan"))
                row.update({f"live_{q}_ms": round(live_ms, 3),
                            f"posthoc_{q}_ms": round(post_ms, 3),
                            f"rel_err_{q}": round(rel, 4)})
                if not (rel < 0.10):
                    parity["ok"] = False
                    failures.append(
                        f"serve {sched} live {q} {live_ms:.2f}ms vs "
                        f"post-hoc {post_ms:.2f}ms ({rel:.1%} >= 10%)")
            parity[sched] = row
            print(f"telemetry/serve_{sched},{row['live_p50_ms']:.0f},"
                  f"rel_err_p50={row['rel_err_p50']}_"
                  f"rel_err_p99={row['rel_err_p99']}")
    finally:
        obs.restore(prev_obs)

    # 5. trace digest: prove the JSONL sink saw the run — span counts
    #    and the plan-source tags the acceptance bar asks for
    trace = {"path": trace_path or None, "records": 0, "spans": {},
             "plan_sources": {}}
    if trace_path and os.path.exists(trace_path):
        with open(trace_path) as f:
            for line in f:
                rec = json.loads(line)
                trace["records"] += 1
                trace["spans"][rec["name"]] = \
                    trace["spans"].get(rec["name"], 0) + 1
                src = (rec.get("attrs") or {}).get("source")
                if rec["name"] == "resolve_call" and src:
                    trace["plan_sources"][src] = \
                        trace["plan_sources"].get(src, 0) + 1
        if not trace["plan_sources"]:
            failures.append("trace has no resolve_call plan-source tags")
        print(f"# trace: {trace['records']} spans -> {trace_path} "
              f"(plan sources: {trace['plan_sources']})")

    if json_path:
        payload = {
            "suite": "telemetry",
            "hw": {"roofline_gb_s": hw.hbm_bw / 1e9},
            "kernels": kernels,
            "graphs": graphs,
            "overhead": {
                "disabled_ms": round(wall["disabled"], 3),
                "enabled_ms": round(wall["enabled"], 3),
                "overhead_frac": round(overhead, 4),
                "gate_frac": 0.03,
                "ok": overhead_ok,
            },
            "serve_parity": parity,
            "trace": trace,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_path}")
    if failures:
        print(f"\nFAILED telemetry gates: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("telemetry ok")


def full() -> None:
    from benchmarks import (fig4_m2c2, kernel_bench, roofline_report,
                            table2_feedforward, table3_microbench)
    failures = []
    for mod in (table2_feedforward, fig4_m2c2, table3_microbench,
                kernel_bench, roofline_report):
        print(f"\n===== {mod.__name__} =====")
        try:
            mod.main()
        except Exception:   # noqa: BLE001 — report all benches
            failures.append(mod.__name__)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED benches: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("\nall benches ok")


def _resolve_out(path: str, out_dir: str) -> str:
    """Route a bare artifact filename into ``out_dir``. Explicit paths —
    absolute, or containing a separator — pass through untouched, as does
    '' (report disabled) and the default out dir ('.')."""
    if not path or not out_dir or out_dir == ".":
        return path
    if os.path.isabs(path) or os.sep in path:
        return path
    os.makedirs(out_dir, exist_ok=True)
    return os.path.join(out_dir, path)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="run every registered kernel at tiny shapes "
                             "instead of the modeled benches")
    parser.add_argument("--json", default="BENCH_smoke.json",
                        help="path for the smoke-mode JSON report "
                             "('' disables; default %(default)s)")
    parser.add_argument("--autotune", action="store_true",
                        help="run the measured autotuner over every "
                             "registry kernel and write the tuned-vs-"
                             "analytic report (composes with --smoke)")
    parser.add_argument("--autotune-json", default="BENCH_autotune.json",
                        help="path for the autotune JSON report "
                             "('' disables; default %(default)s)")
    parser.add_argument("--budget-s", type=float, default=None,
                        help="total wall-time budget for --autotune "
                             "measurement (seconds; default unbounded)")
    parser.add_argument("--graph", action="store_true",
                        help="bench every registered StreamGraph (fused vs "
                             "staged vs unfused) and write the graph JSON "
                             "report (composes with the other modes)")
    parser.add_argument("--graph-json", default="BENCH_graph.json",
                        help="path for the graph JSON report "
                             "('' disables; default %(default)s)")
    parser.add_argument("--sharded", action="store_true",
                        help="run every shardable registry kernel under "
                             "shard_map on a forced 8-device host mesh "
                             "(local- vs global-planned) and write the "
                             "sharded JSON report (composes with the "
                             "other modes)")
    parser.add_argument("--sharded-json", default="BENCH_sharded.json",
                        help="path for the sharded JSON report "
                             "('' disables; default %(default)s)")
    parser.add_argument("--serve", action="store_true",
                        help="run the continuous-batching serving bench "
                             "(paged vs lockstep over a Poisson trace) and "
                             "write the serve JSON report; --smoke shrinks "
                             "the trace (and is consumed: the kernel smoke "
                             "suite does not also run)")
    parser.add_argument("--serve-json", default="BENCH_serve.json",
                        help="path for the serve JSON report "
                             "('' disables; default %(default)s)")
    parser.add_argument("--plans", action="store_true",
                        help="run the plan-service round trip (record a "
                             "serve traffic profile, sweep it offline into "
                             "a PlanDB, replay fresh-process and gate the "
                             "plan-cache hit rate >= 0.9); --smoke shrinks "
                             "the trace (and is consumed, like --serve)")
    parser.add_argument("--plans-json", default="BENCH_plans.json",
                        help="path for the plans JSON report "
                             "('' disables; default %(default)s)")
    parser.add_argument("--plans-db-out", default="PLANDB_swept.json",
                        help="where to copy the swept PlanDB artifact "
                             "('' disables; default %(default)s)")
    parser.add_argument("--chaos", action="store_true",
                        help="run the fault-injection suite (kill/restart "
                             "bitwise resume with plan-snapshot pre-warm, "
                             "SIGTERM drain, pod-eviction remesh, "
                             "straggler rebalance) and gate on every "
                             "scenario; --smoke shrinks step counts (and "
                             "is consumed, like --serve)")
    parser.add_argument("--chaos-json", default="BENCH_chaos.json",
                        help="path for the chaos JSON report "
                             "('' disables; default %(default)s)")
    parser.add_argument("--telemetry", action="store_true",
                        help="run the bandwidth-utilization telemetry "
                             "suite (achieved GB/s + roofline fraction "
                             "per kernel and per graph edge under live "
                             "tracing) and gate the telemetry stack: "
                             "span overhead < 3%%, serve live-vs-post-"
                             "hoc p50/p99 within 10%%; --smoke is "
                             "consumed, like --serve")
    parser.add_argument("--telemetry-json", default="BENCH_telemetry.json",
                        help="path for the telemetry JSON report "
                             "('' disables; default %(default)s)")
    parser.add_argument("--trace-jsonl", default="BENCH_trace.jsonl",
                        help="JSONL span-trace sink for --telemetry "
                             "('' keeps spans in memory; default "
                             "%(default)s)")
    parser.add_argument("--out-dir", default=".",
                        help="directory where bare artifact filenames "
                             "from the --*-json/--plans-db-out/"
                             "--trace-jsonl flags land (explicit paths "
                             "pass through; default %(default)s)")
    args = parser.parse_args()
    for flag in ("json", "autotune_json", "graph_json", "sharded_json",
                 "serve_json", "plans_json", "plans_db_out", "chaos_json",
                 "telemetry_json", "trace_jsonl"):
        setattr(args, flag, _resolve_out(getattr(args, flag), args.out_dir))
    if args.sharded and "jax" not in sys.modules:
        # must land before the first jax import anywhere in the process
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = \
                f"{flags} --xla_force_host_platform_device_count=8".strip()
    if args.smoke and not (args.serve or args.plans or args.chaos
                           or args.telemetry):
        smoke(args.json)
    if args.autotune:
        autotune_bench(args.autotune_json, args.budget_s)
    if args.graph:
        graph_bench(args.graph_json)
    if args.sharded:
        sharded_bench(args.sharded_json)
    if args.serve:
        serve_bench_mode(args.serve_json, smoke=args.smoke)
    if args.plans:
        plans_bench(args.plans_json, smoke=args.smoke,
                    budget_s=args.budget_s, db_out=args.plans_db_out)
    if args.chaos:
        chaos_bench(args.chaos_json, smoke=args.smoke)
    if args.telemetry:
        telemetry_bench(args.telemetry_json, args.trace_jsonl,
                        smoke=args.smoke)
    if not (args.smoke or args.autotune or args.graph or args.sharded
            or args.serve or args.plans or args.chaos or args.telemetry):
        full()


if __name__ == "__main__":
    main()
