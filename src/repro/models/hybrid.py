"""zamba2 hybrid stack: Mamba2 blocks + one shared attention block.

Layer layout (attn_every_n = k): segments of k Mamba2 blocks, each segment
followed by one application of the *shared* transformer block (GQA attention
+ MLP, single weight set, one KV cache per application). 54 Mamba2 layers /
k=6 -> 9 shared-block applications. The Mamba2 segment is scanned (stacked
params); shared-block applications are a short unrolled loop over their own
KV caches.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba2, transformer
from repro.runtime.sharding import constrain


def _n_segments(cfg: ArchConfig) -> int:
    k = cfg.attn_every_n or cfg.n_layers
    assert cfg.n_layers % k == 0, (cfg.n_layers, k)
    return cfg.n_layers // k


def specs(cfg: ArchConfig) -> Dict[str, Any]:
    k = cfg.attn_every_n or cfg.n_layers
    one = {
        "norm": L.norm_specs(cfg.norm, cfg.d_model),
        "mixer": mamba2.mamba_specs(cfg),
    }
    stacked = jax.tree.map(
        lambda s: L.ParamSpec((cfg.n_layers, *s.shape), ("layers", *s.axes),
                              s.dtype, s.init, s.scale),
        one, is_leaf=L.is_spec)
    shared = {
        "norm1": L.norm_specs(cfg.norm, cfg.d_model),
        "attn": transformer.attn_specs(cfg),
        "norm2": L.norm_specs(cfg.norm, cfg.d_model),
        "ffn": L.mlp_specs(cfg.d_model, cfg.d_ff, cfg.act),
    }
    return {"mamba_layers": stacked, "shared": shared}


def _mamba_layer(cfg, p, x, cache, lengths):
    h = L.norm_apply(cfg.norm, x, p["norm"])
    out, new_cache = mamba2.mamba_apply(cfg, p["mixer"], h, cache=cache,
                                        lengths=lengths)
    return x + out, new_cache


def _shared_block(cfg, p, x, positions, cache, lengths):
    h = L.norm_apply(cfg.norm, x, p["norm1"])
    attn_out, new_cache = transformer.attn_apply(
        cfg, p["attn"], h, positions=positions, cache=cache, lengths=lengths)
    x = x + attn_out
    h = L.norm_apply(cfg.norm, x, p["norm2"])
    x = x + L.mlp_apply(p["ffn"], h, cfg.act)
    return constrain(x, ("batch", "seq", "embed")), new_cache


def forward(cfg: ArchConfig, params, x, *, positions, caches=None,
            lengths=None, want_cache: bool = False):
    """x: [B,S,D]. caches: {"mamba": stacked [L,...], "attn": [n_seg, ...]}.
    Returns (x, new_caches, aux)."""
    nseg = _n_segments(cfg)
    k = cfg.attn_every_n or cfg.n_layers
    remat = cfg.remat != "none"

    mamba_fn = _mamba_layer
    shared_fn = _shared_block
    if remat:
        policy = jax.checkpoint_policies.nothing_saveable
        mamba_fn = jax.checkpoint(mamba_fn, policy=policy, static_argnums=(0,))
        shared_fn = jax.checkpoint(shared_fn, policy=policy,
                                   static_argnums=(0,))

    new_mamba_caches = []
    new_attn_caches = []
    lp = params["mamba_layers"]
    for seg in range(nseg):
        seg_params = jax.tree.map(lambda a: a[seg * k:(seg + 1) * k], lp)
        seg_caches = None
        if caches is not None:
            seg_caches = jax.tree.map(
                lambda a: a[seg * k:(seg + 1) * k], caches["mamba"])

        if cfg.scan_layers:
            if caches is not None:
                def body(carry, xs):
                    p, cache = xs
                    xx, nc = mamba_fn(cfg, p, carry, cache, lengths)
                    return xx, nc
                x, seg_new = jax.lax.scan(body, x, (seg_params, seg_caches))
            else:
                def body_nc(carry, p):
                    xx, nc = mamba_fn(cfg, p, carry, None, lengths)
                    if not want_cache:
                        nc = None
                    return xx, nc
                x, seg_new = jax.lax.scan(body_nc, x, seg_params)
        else:
            outs = []
            for i in range(k):
                p_i = jax.tree.map(lambda a: a[i], seg_params)
                c_i = (jax.tree.map(lambda a: a[i], seg_caches)
                       if seg_caches is not None else None)
                x, nc = mamba_fn(cfg, p_i, x, c_i, lengths)
                outs.append(nc if (want_cache or caches is not None) else None)
            seg_new = (jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
                       if outs[0] is not None else None)
        new_mamba_caches.append(seg_new)
        attn_cache = caches["attn"][seg] if caches is not None else None
        x, nac = shared_fn(cfg, params["shared"], x, positions, attn_cache,
                           lengths)
        if want_cache or caches is not None:
            new_attn_caches.append(nac)

    new_caches = None
    if want_cache or caches is not None:
        mamba_stack = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_mamba_caches) \
            if new_mamba_caches[0] is not None else None
        new_caches = {"mamba": mamba_stack, "attn": new_attn_caches}
    return x, new_caches, jnp.zeros((), jnp.float32)


def cache_spec(cfg: ArchConfig, batch: int, s_max: int):
    nseg = _n_segments(cfg)
    m_one, m_axes = mamba2.mamba_cache_spec(cfg, batch)
    m_spec = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_layers, *s.shape), s.dtype),
        m_one)
    m_axes = jax.tree.map(lambda a: ("layers", *a), m_axes,
                          is_leaf=lambda x: isinstance(x, tuple))
    a_one, a_axes = transformer.attn_cache_spec(cfg, batch, s_max)
    spec = {"mamba": m_spec, "attn": [a_one] * nseg}
    axes = {"mamba": m_axes, "attn": [a_axes] * nseg}
    return spec, axes
