from repro.kernels.ff_layer.kernel import build_matmul_program, \
    build_swiglu_program

__all__ = ["build_matmul_program", "build_swiglu_program"]
