"""Analytic cycle model of the feed-forward (DAE) pipeline.

The paper evaluates on an Arria-CX FPGA board with Intel's on-chip profiler.
This container has no FPGA and no TPU, so the quantitative engine of the
reproduction is an explicit analytic model of a decoupled access/execute
pipeline. It models, in seconds:

* the **baseline** ("single work-item") kernel, where loads are *entangled*
  with compute: the conservative compiler serializes the loop whenever it
  suspects a memory loop-carried dependency (false MLCD -> initiation
  interval II >> 1), and divergence/DLCDs stall the load units;
* the **feed-forward** kernel pair, where the producer streams words through
  a pipe of ``depth`` slots, so memory time and compute time *overlap* and
  the steady-state word time is max(t_mem, t_comp) instead of their sum;
* **multiple producers/consumers** (M2C2 etc.), which raise achievable
  memory-level parallelism until the memory system saturates — with a
  contention penalty for irregular access (the paper's Table 3 effect).

The model is deliberately simple, fully documented, and property-tested
(tests/test_pipeline_model.py): pipelining can never make a kernel slower
than the sum of its parts predicts, depth beyond the latency-hiding point
changes nothing (the paper's "depth does not significantly affect
performance"), and stream count saturates at the memory system's knee
(the paper's ">2x2 does not help").

Two hardware presets are provided:

* :data:`ARRIA_CX` — the paper's board (34.1 GB/s DDR4, ~300 MHz fabric);
  used by the benchmark suite to reproduce the paper's tables.
* :data:`TPU_V5E` — the deployment target (819 GB/s HBM, 197 TFLOP/s bf16);
  used by the planner to size pipes for the Pallas kernels.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.pipe import Pipe


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Memory/compute machine model for the DAE pipeline."""

    name: str
    clock_hz: float                   # fabric clock for II-denominated stalls
    hbm_bw: float                     # peak global-memory bandwidth, bytes/s
    stream_bw_frac: float             # fraction of peak one producer can pull
    dma_latency_s: float              # issue->first-byte latency of one copy
    flops: float                      # peak compute, FLOP/s
    irregular_eff: float              # bandwidth derate for irregular access
    contention_coeff: float           # per-extra-stream penalty (irregular)
    max_streams: int                  # memory-system saturation knee

    def stream_bandwidth(self, streams: int, regular: bool) -> float:
        """Aggregate achievable bandwidth for ``streams`` concurrent producers."""
        streams = min(streams, self.max_streams)
        eff = 1.0 if regular else self.irregular_eff
        per_stream = self.hbm_bw * self.stream_bw_frac * eff
        if not regular:
            # concurrent irregular streams fight for row buffers / channels
            per_stream = per_stream / (1.0 + self.contention_coeff * (streams - 1))
        return min(self.hbm_bw * eff, streams * per_stream)


# The paper's board: Intel PAC, Arria CX, 2x4GB DDR4 @ 34.1 GB/s.
ARRIA_CX = HardwareModel(
    name="arria-cx-pac",
    clock_hz=300e6,
    hbm_bw=34.1e9,
    stream_bw_frac=0.55,     # one in-order LSU stream cannot saturate DDR4
    dma_latency_s=300e-9,
    flops=1.5e12,
    irregular_eff=0.18,      # Wang et al. [17]: random access collapses DDR bw
    contention_coeff=0.85,
    max_streams=4,
)

# Deployment target: TPU v5e chip (assignment constants).
TPU_V5E = HardwareModel(
    name="tpu-v5e",
    clock_hz=940e6,
    hbm_bw=819e9,
    stream_bw_frac=0.55,     # one DMA queue's practical share of HBM
    dma_latency_s=2e-6,
    flops=197e12,
    irregular_eff=0.25,
    contention_coeff=0.6,
    max_streams=4,
)


@dataclasses.dataclass(frozen=True)
class Workload:
    """One kernel's stream program, in pipe words.

    Attributes:
      n_words: number of pipe words (tiles) the kernel processes.
      word_bytes: global-memory bytes loaded per word.
      flops_per_word: arithmetic work per word.
      regular: access pattern of the loads (paper: R vs IR).
      divergence: mean fractional control-flow bubble per word when control
        flow is *entangled* with the loads (baseline); in the FF design the
        bubble moves to the consumer and is smoothed across consumers.
      dlcd_cycles: length (cycles) of the data loop-carried dependency chain
        per word (reductions etc.). In the baseline this stalls the *loads*;
        in the FF design it bounds only the consumer.
      false_mlcd_ii: initiation interval (cycles) the conservative compiler
        assigns the baseline loop for a suspected-but-false memory LCD
        (paper: FW=285, BackProp=416). 0 = compiler proves independence.
      store_bytes_per_word: global stores per word (both designs keep stores).
    """

    n_words: int
    word_bytes: float
    flops_per_word: float
    regular: bool = True
    divergence: float = 0.0
    dlcd_cycles: float = 0.0
    false_mlcd_ii: float = 0.0
    store_bytes_per_word: float = 0.0

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_word / max(self.word_bytes, 1e-30)


@dataclasses.dataclass(frozen=True)
class PipelineEstimate:
    """Model output for one design point."""

    total_s: float
    t_mem_word_s: float
    t_comp_word_s: float
    achieved_bw: float          # bytes/s pulled from global memory
    bottleneck: str             # "memory" | "compute" | "latency" | "ii"
    vmem_bytes: int

    @property
    def achieved_bw_mb_s(self) -> float:
        return self.achieved_bw / 1e6


def _word_mem_bytes(w: Workload) -> float:
    return w.word_bytes + w.store_bytes_per_word


_BURST_LSU_OUTSTANDING = 16   # burst-coalesced LSU request buffer depth


def estimate_baseline(w: Workload, hw: HardwareModel) -> PipelineEstimate:
    """Single work-item kernel: loads entangled with compute.

    A *well-pipelined* baseline loop (no LCD) still achieves II=1 with the
    burst-coalesced LSU hiding latency over its request buffer — that is why
    the paper's saturated kernels (PageRank, Hotspot) see ~1x from FF. What
    the baseline cannot escape: the compiler-assigned II from (suspected)
    MLCDs / DLCD chains serializes the *whole* loop, and divergence bubbles
    stall the load units (control flow entangled with addresses).
    """
    bw = hw.stream_bandwidth(1, w.regular)
    t_transfer = _word_mem_bytes(w) / bw
    t_compute = max(w.flops_per_word / hw.flops,
                    w.dlcd_cycles / hw.clock_hz)
    t_lat = (0.0 if w.regular
             else hw.dma_latency_s / _BURST_LSU_OUTSTANDING)
    # divergence inflates everything entangled with the loads — including
    # the DLCD chain; the false-MLCD II is a fixed compiler schedule
    serial = max(t_lat, t_transfer, t_compute, 1.0 / hw.clock_hz) \
        * (1.0 + w.divergence)

    t_ii = w.false_mlcd_ii / hw.clock_hz
    t_word = max(serial, t_ii)
    bottleneck = "ii" if t_ii >= serial and w.false_mlcd_ii > 0 else (
        "memory" if t_transfer >= t_compute else "compute")
    total = w.n_words * t_word
    return PipelineEstimate(
        total_s=total,
        t_mem_word_s=t_transfer,
        t_comp_word_s=t_compute,
        achieved_bw=w.n_words * _word_mem_bytes(w) / total,
        bottleneck=bottleneck,
        vmem_bytes=0,
    )


def estimate_feedforward(
    w: Workload,
    hw: HardwareModel,
    pipe: Pipe,
    consumers: Optional[int] = None,
) -> PipelineEstimate:
    """Feed-forward kernel pair connected by ``pipe``.

    Steady state: producer and consumer overlap; the word time is the max of
    the two stages. The producer is free of DLCD/divergence (paper's whole
    point); the false MLCD vanishes because the split *proves* independence.

    Latency exposure: a *regular* stream is serviced by a prefetching LSU /
    streaming DMA — issue latency amortizes over the stream and only the
    pipeline fill pays it. An *irregular* stream pays latency per word,
    hidden by (depth-1) x streams outstanding transactions, but concurrent
    irregular streams also contend for the memory system's transaction
    resources (the paper's Table-3 effect). The pipelined loop itself can
    retire at most one word per clock (II=1 floor).
    """
    producers = pipe.streams
    consumers = producers if consumers is None else consumers

    bw = hw.stream_bandwidth(producers, w.regular)
    t_transfer = _word_mem_bytes(w) / bw
    if w.regular:
        t_latency_exposed = 0.0
    else:
        outstanding = max(pipe.depth - 1, 1) * producers
        lat = hw.dma_latency_s * (1.0 + hw.contention_coeff * (producers - 1))
        t_latency_exposed = lat / outstanding
    t_mem = max(t_transfer, t_latency_exposed)

    t_flops = w.flops_per_word / hw.flops
    t_dlcd = w.dlcd_cycles / hw.clock_hz
    # divergence bubbles smooth across consumers (static parity balancing)
    t_comp = (max(t_flops, t_dlcd) * (1.0 + w.divergence / consumers)) / consumers \
        if consumers > 1 else max(t_flops, t_dlcd) * (1.0 + w.divergence)

    t_word = max(t_mem, t_comp, 1.0 / hw.clock_hz)   # II=1 retirement floor
    fill = hw.dma_latency_s + pipe.depth * t_mem          # pipeline warmup
    total = fill + w.n_words * t_word
    if t_word == t_mem and t_mem == t_latency_exposed and t_latency_exposed > t_transfer:
        bottleneck = "latency"
    else:
        bottleneck = "memory" if t_mem >= t_comp else "compute"
    return PipelineEstimate(
        total_s=total,
        t_mem_word_s=t_mem,
        t_comp_word_s=t_comp,
        achieved_bw=w.n_words * _word_mem_bytes(w) / total,
        bottleneck=bottleneck,
        vmem_bytes=pipe.vmem_bytes,
    )


def speedup(w: Workload, hw: HardwareModel, pipe: Pipe,
            consumers: Optional[int] = None) -> float:
    """FF speedup over the single work-item baseline (paper Table 2 metric)."""
    base = estimate_baseline(w, hw)
    ff = estimate_feedforward(w, hw, pipe, consumers)
    return base.total_s / ff.total_s


# ---------------------------------------------------------------------------
# Multi-kernel graphs (MKPipe-style stage overlap)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GraphStage:
    """One node of a compiled :mod:`repro.core.graph` program, as the model
    sees it.

    ``fused_with_prev`` marks the in-edge from the previous stage as fused:
    the previous stage's output never stores to HBM
    (``saved_store_bytes``) and this stage's reloads of it are served from
    the in-VMEM ring (``saved_load_bytes``); the two stages overlap
    MKPipe-style instead of running back to back. ``rationale`` carries the
    fuser's per-edge decision line (fused: why legal; staged: why rejected)
    so bench reports can surface it without recompiling.
    """

    name: str
    workload: Workload
    pipe: Pipe
    fused_with_prev: bool = False
    saved_load_bytes: float = 0.0
    saved_store_bytes: float = 0.0
    rationale: str = ""


@dataclasses.dataclass(frozen=True)
class EdgeEstimate:
    """Model output for one graph edge (surfaced in BENCH_graph.json)."""

    edge: str                   # "producer->consumer"
    mode: str                   # "fused" | "staged"
    hbm_bytes_saved: float
    rationale: str


@dataclasses.dataclass(frozen=True)
class GraphEstimate:
    """Model output for one compiled multi-kernel graph.

    ``total_s`` models the chosen lowering (fused segments overlap, staged
    boundaries serialize); ``unfused_s`` is every stage alone with full HBM
    traffic — the two-calls baseline the paper's memory-controller-wall
    argument is made against. ``skipped`` mirrors ``Plan.skipped``: one
    line per staged edge explaining *why* it did not fuse, so fusion
    rejections are observable from the bench JSON without rerunning.
    """

    total_s: float
    unfused_s: float
    per_stage: Tuple[Tuple[str, PipelineEstimate], ...]
    edges: Tuple[EdgeEstimate, ...]
    hbm_bytes_saved: float
    skipped: Tuple[str, ...]

    @property
    def overlap_speedup(self) -> float:
        return self.unfused_s / max(self.total_s, 1e-30)


def _adjusted(w: Workload, saved_load: float, saved_store: float) -> Workload:
    """Remove fused-edge HBM traffic from one stage's workload (the bytes
    now travel through VMEM rings instead of the memory controller)."""
    per_word_load = saved_load / max(w.n_words, 1)
    per_word_store = saved_store / max(w.n_words, 1)
    return dataclasses.replace(
        w,
        word_bytes=max(w.word_bytes - per_word_load, 0.0),
        store_bytes_per_word=max(w.store_bytes_per_word - per_word_store, 0.0),
    )


def estimate_graph(stages: Tuple[GraphStage, ...],
                   hw: HardwareModel, *,
                   extra_edges: Tuple[EdgeEstimate, ...] = ()
                   ) -> GraphEstimate:
    """Estimate a multi-kernel pipe graph (MKPipe, arXiv 2002.01614).

    Stages are given in topological (execution) order. Consecutive stages
    joined by a fused edge form a *segment*: their workloads shed the
    intermediate's HBM traffic and the segment's time is the max of its
    members plus one fill (producer and consumer overlap, like the paper's
    producer/consumer kernels overlap within one kernel). Staged edges
    serialize: the intermediate round-trips HBM and segment times add up —
    exactly the memory-controller round trip the fused lowering removes.

    ``extra_edges`` carries graph edges that do not join *consecutive*
    stages — a ring-served residual feeding a later chain member, or a
    multi-consumer skip edge. They are appended to ``edges`` verbatim,
    their savings count toward ``hbm_bytes_saved``, and staged ones with a
    rationale surface in ``skipped`` — so every edge of a whole-layer
    graph stays observable even when the stage sequence cannot express it.
    """
    if not stages:
        raise ValueError("estimate_graph needs at least one stage")

    # per-stage workloads with fused-edge traffic removed
    adj: list = [s.workload for s in stages]
    for i, s in enumerate(stages):
        if not s.fused_with_prev:
            continue
        adj[i - 1] = _adjusted(adj[i - 1], 0.0, s.saved_store_bytes)
        adj[i] = _adjusted(adj[i], s.saved_load_bytes, 0.0)

    per_stage = []
    edges = []
    skipped = []
    saved_total = 0.0
    total = 0.0
    unfused = 0.0
    seg_max = 0.0
    for i, s in enumerate(stages):
        est = estimate_feedforward(adj[i], hw, s.pipe)
        per_stage.append((s.name, est))
        unfused += estimate_feedforward(s.workload, hw, s.pipe).total_s
        if i > 0:
            prev = stages[i - 1]
            saved = (s.saved_load_bytes + s.saved_store_bytes) \
                if s.fused_with_prev else 0.0
            saved_total += saved
            edges.append(EdgeEstimate(
                edge=f"{prev.name}->{s.name}",
                mode="fused" if s.fused_with_prev else "staged",
                hbm_bytes_saved=saved,
                rationale=s.rationale,
            ))
            if not s.fused_with_prev and s.rationale:
                skipped.append(f"{prev.name}->{s.name}: {s.rationale}")
        if s.fused_with_prev:
            # overlap with the running segment: the segment retires at the
            # pace of its slowest member
            seg_max = max(seg_max, est.total_s)
        else:
            total += seg_max
            seg_max = est.total_s
    total += seg_max
    for e in extra_edges:
        edges.append(e)
        if e.mode == "fused":
            saved_total += e.hbm_bytes_saved
        elif e.rationale:
            skipped.append(f"{e.edge}: {e.rationale}")
    return GraphEstimate(
        total_s=total,
        unfused_s=unfused,
        per_stage=tuple(per_stage),
        edges=tuple(edges),
        hbm_bytes_saved=saved_total,
        skipped=tuple(skipped),
    )
