"""Quickstart: the feed-forward pipe stack in five minutes.

1. Plan a pipe for a workload (the paper's depth/streams decisions, automated).
2. Run a DAE Pallas kernel against its oracle (interpret mode on CPU).
3. Build an assigned architecture, run a train step and a prefill+decode.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (TPU_V5E, Pipe, Workload, estimate_baseline,
                        estimate_feedforward, plan_pipe)
from repro.kernels.ff_matmul import matmul, matmul_ref


def pipe_planning():
    print("== 1. pipe planning (paper §3, automated) ==")
    w = Workload(n_words=4096, word_bytes=128 * 128 * 4,
                 flops_per_word=2 * 128 * 128 * 128, regular=True)
    plan = plan_pipe(w, tile=(128, 128), dtype=jnp.float32)
    base = estimate_baseline(w, TPU_V5E)
    ff = estimate_feedforward(w, TPU_V5E, plan.pipe)
    print(f" plan: depth={plan.pipe.depth} streams={plan.pipe.streams} "
          f"vmem={plan.pipe.vmem_bytes >> 10} KiB")
    print(f" modeled: baseline {base.total_s * 1e3:.2f} ms -> "
          f"ff {ff.total_s * 1e3:.2f} ms ({base.total_s / ff.total_s:.1f}x); "
          f"{plan.rationale}")


def kernel_demo():
    print("== 2. DAE kernel vs oracle (interpret mode) ==")
    import repro

    k = jax.random.key(0)
    a = jax.random.normal(k, (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(k, 1), (256, 256), jnp.float32)
    ref = matmul_ref(a, b)
    # explicit per-call policy (the paper's programmer-chosen sizing)
    out = repro.ops.matmul(a, b, policy=repro.PipePolicy(depth=3, streams=2))
    print(f" ops.matmul(depth=3, streams=2) max|err| = "
          f"{float(jnp.max(jnp.abs(out - ref))):.2e}")
    # session defaults: planner-sized ff vs the synchronous baseline
    with repro.policy(mode="baseline"):
        base = matmul(a, b)
    print(f" baseline (depth=1 via repro.policy) max|err| = "
          f"{float(jnp.max(jnp.abs(base - ref))):.2e}")


def model_demo():
    print("== 3. assigned architecture: train + serve ==")
    from repro.configs.base import smoke_config
    from repro.launch import steps as steps_lib
    from repro.models import build_model
    from repro.optim import adamw

    cfg = smoke_config("llama3_2_1b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    print(f" llama3.2-style smoke model: {model.param_count():,} params")

    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.key(2), (2, 32), 0, cfg.vocab),
    }
    train_step = jax.jit(steps_lib.make_train_step(model))
    params2, _, metrics = train_step(params, adamw.init(params), batch)
    print(f" one train step: loss={float(metrics['loss']):.4f} "
          f"gnorm={float(metrics['grad_norm']):.3f}")

    logits, cache = model.prefill(params, {"tokens": batch["tokens"]})
    tok = jnp.argmax(logits, axis=-1)
    print(f" prefill -> first sampled tokens: {np.asarray(tok)}")


if __name__ == "__main__":
    pipe_planning()
    kernel_demo()
    model_demo()
    print("quickstart done")
