"""Fleet-scale plan service: record -> sweep -> merge -> ship -> prewarm.

The measured autotuner (:mod:`repro.core.autotune`) turns call sites into
tuned plans, but its cache is one private JSON per host, tuned against
whatever shapes happened to run. This package promotes tuning to a managed
artifact pipeline:

* **record** — :func:`record_traffic` captures the real workload
  distribution of a run (serving ``--record-profile``, training, tests)
  into a shape-bucketed :class:`TrafficProfile`;
* **sweep** — :func:`sweep_profile` (CLI: ``python -m repro.plans sweep``)
  tunes offline from that profile under a time budget, highest
  frequency x modeled cost first;
* **merge** — :class:`PlanDB` artifacts from heterogeneous hosts combine
  deterministically (newer measurement wins per key, conflicts logged,
  foreign namespaces preserved bitwise);
* **ship + prewarm** — the merged DB rides with a release
  (``REPRO_PLAN_DB`` / ``tuning_config(plan_db=...)``); ``autotune``
  consults it after the per-host cache and before measuring, and
  :func:`prewarm` parses it once at startup.

Namespacing (:mod:`repro.plans.registry`) keys records by hardware
fingerprint so one artifact serves a mixed fleet.
"""

from repro.plans.plandb import (      # noqa: F401
    PLANDB_FORMAT_VERSION,
    MergeReport,
    PlanDB,
    PlanDBError,
    content_hash,
    prewarm,
)
from repro.plans.profile import (     # noqa: F401
    PROFILE_FORMAT_VERSION,
    ProfileEntry,
    TrafficProfile,
    bucket_site,
    bucket_value,
    record_traffic,
)
from repro.plans.registry import (    # noqa: F401
    DEFAULT_NAMESPACE,
    hardware_fingerprint,
    plan_namespace,
    register_fingerprint_resolver,
)
from repro.plans.sweep import SweepResult, sweep_profile   # noqa: F401
