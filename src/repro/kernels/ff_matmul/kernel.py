"""Feed-forward (DAE) blocked matmul: C = A @ B.

The paper's transformation, applied to the canonical MXU workload:

* memory kernel  = async HBM->VMEM copies of A/B tiles, issued ``depth-1``
  words ahead through two ring pipes (one per operand);
* compute kernel = MXU dot over the landed tiles, accumulating in VMEM f32;
* pipe           = the ring buffers; ``streams`` splits each tile copy into
  parallel sub-DMAs (multi-producer M2C2 analogue).

``depth=1`` degenerates to synchronous copy-then-compute — the "single
work-item" baseline used by the Table-2 benchmark.

Word schedule: 1-D grid over (mi, ni, ki) with k innermost; the output block
(mi, ni) is revisited for nK consecutive steps and written on the last.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pipe import Pipe
from repro.kernels.dae import RingPipe, dae_acquire, dae_release, ring_scratch


def _kernel(a_hbm, b_hbm, o_ref, acc, a_buf, a_sems, b_buf, b_sems,
            *, nm: int, nn: int, nk: int, a_pipe: Pipe, b_pipe: Pipe,
            out_dtype):
    g = pl.program_id(0)
    n_words = nm * nn * nk
    ki = g % nk
    ni = (g // nk) % nn
    mi = g // (nk * nn)
    bm, bk = a_pipe.tile
    _, bn = b_pipe.tile

    def a_slice(word):
        w_ki = word % nk
        w_mi = word // (nk * nn)
        return a_hbm.at[pl.ds(w_mi * bm, bm), pl.ds(w_ki * bk, bk)]

    def b_slice(word):
        w_ki = word % nk
        w_ni = (word // nk) % nn
        return b_hbm.at[pl.ds(w_ki * bk, bk), pl.ds(w_ni * bn, bn)]

    pipes = [
        RingPipe(a_buf, a_sems, a_pipe, a_slice),
        RingPipe(b_buf, b_sems, b_pipe, b_slice),
    ]
    dae_acquire(g, n_words, pipes, a_pipe.depth)

    @pl.when(ki == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    a_tile = pipes[0].word_ref(g)[...]
    b_tile = pipes[1].word_ref(g)[...]
    acc[...] += jnp.dot(a_tile, b_tile, preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _():
        o_ref[...] = acc[...].astype(out_dtype)

    dae_release(g, n_words, pipes, a_pipe.depth)


@functools.partial(
    jax.jit,
    static_argnames=("block", "depth", "streams", "out_dtype", "interpret"))
def matmul_ff(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block: Tuple[int, int, int] = (128, 128, 128),
    depth: int = 2,
    streams: int = 1,
    out_dtype=None,
    interpret: bool = True,
) -> jnp.ndarray:
    """DAE-pipelined matmul. Shapes must be multiples of ``block`` (use
    ops.matmul for auto-padding)."""
    (m, k), (k2, n) = a.shape, b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = block
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, block)
    nm, nn, nk = m // bm, n // bn, k // bk
    out_dtype = out_dtype or a.dtype

    a_pipe = Pipe(tile=(bm, bk), dtype=a.dtype, depth=depth, streams=streams)
    b_pipe = Pipe(tile=(bk, bn), dtype=b.dtype, depth=depth, streams=streams)

    kernel = functools.partial(
        _kernel, nm=nm, nn=nn, nk=nk, a_pipe=a_pipe, b_pipe=b_pipe,
        out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(nm * nn * nk,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec(
            (bm, bn), lambda g: (g // (nn * nk), (g // nk) % nn)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            *ring_scratch(a_pipe),
            *ring_scratch(b_pipe),
        ],
        interpret=interpret,
    )(a, b)
