"""Mesh topology as a planner input: :class:`MeshSpec`.

The Memory Controller Wall (arXiv 1910.06726) argues that interface-level
bandwidth planning must account for the *actual device topology* — a plan
sized for one memory system silently mis-sizes another. At mesh scale the
same hazard appears one level up: a pipe plan tuned on a single device (or
an 8-way data-parallel mesh) must never be served to a call site running
under a different topology, and a kernel running *inside* ``shard_map``
works on per-shard local shapes, not the global array.

:class:`MeshSpec` is the frozen, hashable summary of that topology — axis
names/sizes and the derived device count — used three ways:

* as a :class:`~repro.core.program.PipePolicy` field (``policy.mesh``), so
  plans and tuned-plan cache keys are topology-scoped;
* as the planner's localization input: :func:`localize_workload` divides a
  global word schedule across the mesh's workload-splitting shards;
* as the ambient default: :func:`ambient_mesh` picks up the installed
  :class:`repro.runtime.sharding.ShardingContext` without core ever
  importing the runtime layer at module scope.

Core stays importable without a mesh: everything degrades to
:data:`SINGLE_DEVICE` (one shard, empty axes) when no mesh is involved.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.pipeline_model import Workload


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Hashable mesh-topology summary (axis names/sizes, device count).

    ``axes`` is the ordered ``((name, size), ...)`` tuple of the mesh.
    An empty tuple is the single-device topology. Build one from a live
    ``jax.sharding.Mesh`` with :meth:`from_mesh`, or from an installed
    :class:`~repro.runtime.sharding.ShardingContext` via its
    ``mesh_spec()`` method.
    """

    axes: Tuple[Tuple[str, int], ...] = ()

    def __post_init__(self):
        for ax in self.axes:
            name, size = ax
            if not isinstance(name, str) or int(size) < 1:
                raise ValueError(f"bad mesh axis {ax!r}")

    @classmethod
    def from_mesh(cls, mesh) -> "MeshSpec":
        """Summarize a ``jax.sharding.Mesh`` (or anything with ``.shape``
        mapping axis names to sizes)."""
        shape = dict(mesh.shape)
        return cls(axes=tuple((str(k), int(v)) for k, v in shape.items()))

    @property
    def device_count(self) -> int:
        n = 1
        for _, size in self.axes:
            n *= size
        return n

    def axis_size(self, name: str) -> int:
        for ax, size in self.axes:
            if ax == name:
                return size
        return 1

    @property
    def token(self) -> str:
        """Cache-key component: ``"single"`` or ``"data4.model2"``."""
        if not self.axes:
            return "single"
        return ".".join(f"{name}{size}" for name, size in self.axes)


SINGLE_DEVICE = MeshSpec()


def ambient_mesh() -> Optional[MeshSpec]:
    """MeshSpec of the installed ambient ShardingContext, if any.

    Imported lazily so ``repro.core`` never depends on the runtime layer
    at module scope (the runtime imports core the other way around).
    """
    try:
        from repro.runtime import sharding
    except Exception:    # noqa: BLE001 — core must work without runtime
        return None
    ctx = sharding.current()
    if ctx is None:
        return None
    return MeshSpec.from_mesh(ctx.mesh)


def resolve_mesh(mesh: Optional[MeshSpec]) -> MeshSpec:
    """The effective topology of a call site: the policy's explicit mesh,
    else the ambient ShardingContext's, else single-device."""
    if mesh is not None:
        return mesh
    return ambient_mesh() or SINGLE_DEVICE


def resolve_sharding(sharding=None) -> Tuple[MeshSpec, int]:
    """Resolve a ``sharding=`` argument to ``(MeshSpec, workload shards)``.

    Accepts a :class:`~repro.runtime.sharding.ShardingContext` (duck-typed:
    anything with ``mesh`` + ``data_shards()``), a :class:`MeshSpec`, or
    ``None`` — which picks up the ambient context, falling back to
    single-device. A bare MeshSpec carries no logical rules, so its shard
    count comes from the ambient context when that context describes the
    *same* topology (the common case: a policy tagged by ``mesh_policy``
    inside ``use_sharding``); otherwise it is conservatively treated as
    fully workload-splitting — every device gets ``1/device_count`` of
    the word schedule.
    """
    def ambient():
        try:
            from repro.runtime import sharding as shlib
            return shlib.current()
        except Exception:    # noqa: BLE001
            return None

    if sharding is None:
        sharding = ambient()
        if sharding is None:
            return SINGLE_DEVICE, 1
    if isinstance(sharding, MeshSpec):
        ctx = ambient()
        if ctx is not None and MeshSpec.from_mesh(ctx.mesh) == sharding:
            return sharding, int(ctx.data_shards())
        return sharding, sharding.device_count
    # ShardingContext: batch-rule-derived data shards, full mesh in the key
    return MeshSpec.from_mesh(sharding.mesh), int(sharding.data_shards())


def localize_workload(w: Workload, shards: int) -> Workload:
    """Per-shard view of a global word schedule: ``shards`` devices each
    stream ``ceil(n_words / shards)`` words; per-word bytes/flops are
    unchanged (the tile geometry is the same on every shard)."""
    shards = max(int(shards), 1)
    if shards == 1:
        return w
    return dataclasses.replace(w, n_words=max(-(-w.n_words // shards), 1))
