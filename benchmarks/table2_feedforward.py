"""Paper Table 2: feed-forward design model vs. single work-item baseline.

For each benchmark: modeled baseline/FF time on the paper's board
(ARRIA_CX), modeled speedup vs. the published number, bandwidth-utilization
before/after, and VMEM (BRAM-analogue) cost. ``--sweep-depth`` reproduces
the paper's depth-insensitivity observation (depths 1 is the baseline; 2,
100, 1000 are the paper's sweep).
"""

from __future__ import annotations

from repro.core import (ARRIA_CX, Pipe, estimate_baseline,
                        estimate_feedforward)
from benchmarks.workloads import BENCHES


def rows(sweep_depth: bool = False):
    out = []
    for name, b in BENCHES.items():
        base = estimate_baseline(b.workload, ARRIA_CX)
        pipe = Pipe(tile=(8, 128), depth=8)
        ff = estimate_feedforward(b.workload, ARRIA_CX, pipe)
        row = {
            "name": name,
            "us_per_call": ff.total_s * 1e6 / b.workload.n_words,
            "baseline_ms": base.total_s * 1e3,
            "ff_ms": ff.total_s * 1e3,
            "speedup": base.total_s / ff.total_s,
            "paper_speedup": b.paper_speedup,
            "bw_before_mb_s": base.achieved_bw_mb_s,
            "bw_after_mb_s": ff.achieved_bw_mb_s,
            "vmem_bytes": ff.vmem_bytes,
        }
        if sweep_depth:
            for d in (2, 100, 1000):
                e = estimate_feedforward(b.workload, ARRIA_CX,
                                         pipe.with_depth(min(d, 1024)))
                row[f"ff_ms_d{d}"] = e.total_s * 1e3
        out.append(row)
    return out


def main(sweep_depth: bool = True):
    print("# Table 2 analogue: FF vs single work-item "
          "(modeled on the paper's Arria CX board)")
    print("name,us_per_call,derived")
    hdr = ("bench", "base ms", "ff ms", "model x", "paper x",
           "bw before", "bw after")
    detail = []
    for r in rows(sweep_depth):
        print(f"table2/{r['name']},{r['us_per_call']:.3f},"
              f"speedup={r['speedup']:.2f}x_paper={r['paper_speedup']:.2f}x")
        detail.append(
            f"  {r['name']:10s} {r['baseline_ms']:10.1f} {r['ff_ms']:9.1f} "
            f"{r['speedup']:7.2f} {r['paper_speedup']:7.2f} "
            f"{r['bw_before_mb_s']:9.0f} {r['bw_after_mb_s']:9.0f} MB/s")
        if sweep_depth:
            ds = " ".join(f"d{d}={r[f'ff_ms_d{d}']:.1f}ms"
                          for d in (2, 100, 1000))
            detail.append(f"             depth sweep: {ds}")
    print("#", " | ".join(hdr))
    for line in detail:
        print("#" + line)
    geo = 1.0
    n = 0
    for r in rows():
        if r["paper_speedup"] > 2:    # the paper's big-win kernels
            geo *= r["speedup"]
            n += 1
    print(f"# geomean modeled speedup over big-win kernels: "
          f"{geo ** (1 / max(n, 1)):.1f}x (paper avg ~20x over all)")


if __name__ == "__main__":
    main(sweep_depth=True)
