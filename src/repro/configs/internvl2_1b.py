"""internvl2-1b [vlm] — InternViT frontend STUBBED to precomputed patch
embeddings; qwen2-0.5b-style LM backbone.
[arXiv:2404.16821; hf]  24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="internvl2_1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    qkv_bias=True,
    rope_theta=1000000.0,
    n_patches=256,
    rule_overrides={"heads": None, "kv_heads": None,   # 14 heads vs 16-way axis
                    "seq": "model"},                   # shard attention by seq instead
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    n_patches=8,
    compute_dtype="float32",
)
