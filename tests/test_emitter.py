"""Ring-pipe emitter tests.

Two layers: (1) the emitter primitives driven directly by tiny streaming-
copy kernels (regular, multi-stream, mixed-depth, gather, deep-ring /
short-grid warmup); (2) every registered ff_* kernel against its ref.py
oracle across pipe depths 1/2/4 and stream counts 1/2 (interpret mode) —
the acceptance bar for the shared-emitter refactor."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.core import GatherRingPipe, Pipe, RingPipe, acquire, release
from repro.kernels.registry import all_kernels

SPECS = {s.name: s for s in all_kernels()}


# ---------------------------------------------------------------------------
# emitter primitives: streaming-copy kernels
# ---------------------------------------------------------------------------

def _copy_kernel(x_hbm, o_ref, buf, sems, *, ring, n_words):
    g = pl.program_id(0)
    rows = ring.spec.tile[0]
    p = ring.bind(buf, sems, lambda w: x_hbm.at[pl.ds(w * rows, rows), :])
    acquire(g, n_words, [p])
    o_ref[...] = p.slot(g)[...]
    release(g, n_words, [p])


def ring_copy(x, depth, streams=1, rows=8):
    n_words = x.shape[0] // rows
    ring = RingPipe(Pipe(tile=(rows, x.shape[1]), dtype=x.dtype,
                         depth=depth, streams=streams))
    return pl.pallas_call(
        functools.partial(_copy_kernel, ring=ring, n_words=n_words),
        grid=(n_words,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((rows, x.shape[1]), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[*ring.scratch_shapes],
        interpret=True,
    )(x)


@pytest.mark.parametrize("depth", [1, 2, 3, 4])
@pytest.mark.parametrize("streams", [1, 2, 4])
def test_ring_copy_roundtrip(depth, streams):
    x = jax.random.normal(jax.random.key(0), (64, 128), jnp.float32)
    np.testing.assert_array_equal(np.asarray(ring_copy(x, depth, streams)),
                                  np.asarray(x))


def test_ring_deeper_than_grid():
    """Warmup prologue must clamp to n_words when the ring is deeper than
    the whole word stream (auto-planned depths hit this at tiny shapes)."""
    x = jax.random.normal(jax.random.key(1), (16, 128), jnp.float32)  # 2 words
    np.testing.assert_array_equal(np.asarray(ring_copy(x, depth=6)),
                                  np.asarray(x))


def _two_pipe_kernel(a_hbm, b_hbm, o_ref, a_buf, a_sems, b_buf, b_sems,
                     *, a_ring, b_ring, n_words):
    g = pl.program_id(0)
    pipes = [a_ring.bind(a_buf, a_sems, lambda w: a_hbm.at[pl.ds(w * 8, 8), :]),
             b_ring.bind(b_buf, b_sems, lambda w: b_hbm.at[pl.ds(w * 8, 8), :])]
    acquire(g, n_words, pipes)
    o_ref[...] = a_ring.slot(g)[...] + b_ring.slot(g)[...]
    release(g, n_words, pipes)


def test_mixed_depth_pipes():
    """Pipes in one kernel may have different depths (the emitter schedules
    each ring's warmup and refill independently)."""
    a = jax.random.normal(jax.random.key(2), (64, 128), jnp.float32)
    b = jax.random.normal(jax.random.key(3), (64, 128), jnp.float32)
    n_words = 8
    a_ring = RingPipe(Pipe(tile=(8, 128), dtype=a.dtype, depth=2))
    b_ring = RingPipe(Pipe(tile=(8, 128), dtype=b.dtype, depth=4, streams=2))
    out = pl.pallas_call(
        functools.partial(_two_pipe_kernel, a_ring=a_ring, b_ring=b_ring,
                          n_words=n_words),
        grid=(n_words,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((8, 128), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        scratch_shapes=[*a_ring.scratch_shapes, *b_ring.scratch_shapes],
        interpret=True,
    )(a, b)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(a + b))


def test_gather_ring_scratch_shapes():
    """The gather emitter owns one semaphore per (slot, row)."""
    ring = GatherRingPipe(Pipe(tile=(8, 128), dtype=jnp.float32, depth=3))
    assert ring.n_dmas == 8
    buf, sems = ring.scratch_shapes
    assert buf.shape == (3, 8, 128)


# ---------------------------------------------------------------------------
# refactored kernels vs. oracles across (depth, streams)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("streams", [1, 2])
@pytest.mark.parametrize("depth", [1, 2, 4])
@pytest.mark.parametrize("name", sorted(SPECS))
def test_kernel_matches_oracle(name, depth, streams):
    spec = SPECS[name]
    args, kw = spec.make_inputs(jax.random.key(7))
    out = np.float32(spec.op(*args, **kw, mode="ff", depth=depth,
                             streams=streams, interpret=True))
    ref = np.float32(spec.op(*args, **kw, mode="ref"))
    if spec.tol == 0:
        np.testing.assert_array_equal(out, ref)
    else:
        np.testing.assert_allclose(out, ref, rtol=spec.tol, atol=spec.tol)


@pytest.mark.parametrize("name", sorted(SPECS))
def test_kernel_auto_plan_matches_oracle(name):
    """depth="auto"/streams="auto" (planner-sized pipes) stay correct."""
    spec = SPECS[name]
    args, kw = spec.make_inputs(jax.random.key(11))
    out = np.float32(spec.op(*args, **kw, mode="ff", depth="auto",
                             streams="auto", interpret=True))
    ref = np.float32(spec.op(*args, **kw, mode="ref"))
    if spec.tol == 0:
        np.testing.assert_array_equal(out, ref)
    else:
        np.testing.assert_allclose(out, ref, rtol=spec.tol, atol=spec.tol)
