"""Roofline machinery: HLO collective parsing and the layer-diff
extrapolation math (the §Roofline pipeline is itself code — test it)."""

import numpy as np
import pytest

from repro.launch.hlo_stats import collective_stats
from repro.launch.roofline import analyze_cell, model_flops

HLO = """
ENTRY %main {
  %ar = f32[1024,512]{1,0} all-reduce(f32[1024,512]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[64,2048]{1,0} all-gather(bf16[8,2048]{1,0} %y), replica_groups=[2,8]<=[16], dimensions={0}
  %rs = f32[128]{0} reduce-scatter(f32[1024]{0} %z), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %cp = bf16[32,128]{1,0} collective-permute(bf16[32,128]{1,0} %w), source_target_pairs={{0,1}}
  %ard = f32[4]{0} all-reduce-done(f32[4]{0} %h)
  %nothing = f32[16]{0} add(f32[16]{0} %a, f32[16]{0} %b)
}
"""


def test_collective_stats_parsing():
    s = collective_stats(HLO, link_bw=50e9)
    assert s["all-reduce"]["count"] == 1
    assert s["all-reduce"]["bytes"] == 1024 * 512 * 4
    # ring model: 2*(g-1)/g * bytes / bw with g=4
    np.testing.assert_allclose(
        s["all-reduce"]["seconds"],
        2 * 3 / 4 * 1024 * 512 * 4 / 50e9, rtol=1e-6)
    assert s["all-gather"]["count"] == 1
    assert s["all-gather"]["bytes"] == 64 * 2048 * 2
    # iota groups [2,8] -> group size 8
    np.testing.assert_allclose(
        s["all-gather"]["seconds"], 7 / 8 * 64 * 2048 * 2 / 50e9, rtol=1e-6)
    assert s["reduce-scatter"]["count"] == 1
    assert s["collective-permute"]["count"] == 1
    np.testing.assert_allclose(
        s["collective-permute"]["seconds"], 32 * 128 * 2 / 50e9, rtol=1e-6)
    assert s["total_count"] == 4          # -done line ignored


def _fake_cell(l1_flops, l2_flops, units):
    coll = {"total_bytes": 0.0, "total_seconds": 0.0, "total_count": 0}
    return {
        "cell": "qwen1_5_0p5b__train_4k__pod16x16",
        "arch": "qwen1_5_0p5b", "shape": "train_4k", "mesh": "pod16x16",
        "ok": True, "n_layer_units": units,
        "n_params": 620_000_000, "n_active_params": 620_000_000,
        "memory": {"peak_bytes_est": 1 << 30, "argument_bytes": 1 << 28,
                   "output_bytes": 0, "temp_bytes": 0, "alias_bytes": 0,
                   "code_bytes": 0},
        "variants": {
            "L1": {"flops": l1_flops, "bytes": 1e9, "collectives": coll},
            "L2": {"flops": l2_flops, "bytes": 1.5e9, "collectives": coll},
        },
    }


def test_layer_diff_extrapolation():
    """total = f(1) + (units-1) * (f(2) - f(1)) — the scan-undercount fix."""
    a = analyze_cell(_fake_cell(l1_flops=10e12, l2_flops=13e12, units=24))
    expect_flops = 10e12 + 23 * 3e12
    np.testing.assert_allclose(a["hlo_flops_per_dev"], expect_flops)
    np.testing.assert_allclose(a["t_compute_s"], expect_flops / 197e12)
    expect_bytes = 1e9 + 23 * 0.5e9
    np.testing.assert_allclose(a["hlo_bytes_per_dev"], expect_bytes)
    assert a["bottleneck"] in ("compute", "memory", "collective")


def test_model_flops_sane():
    """6*N*D-scale sanity for train; decode ~ 2*N*B + attention term."""
    n = 620_000_000
    f_train = model_flops("qwen1_5_0p5b", "train_4k", n)
    d_tokens = 256 * 4096
    assert 0.5 * 6 * n * d_tokens < f_train < 3 * 6 * n * d_tokens
    f_dec = model_flops("qwen1_5_0p5b", "decode_32k", n)
    assert f_dec < f_train / 1000


def test_skipped_and_failed_cells_return_none():
    assert analyze_cell({"skipped": True, "ok": True}) is None
    assert analyze_cell({"ok": False}) is None
