"""grok-1-314b [moe] — 8 experts, top-2.
[hf:xai-org/grok-1; unverified]  64L d_model=6144 48H (GQA kv=8)
d_ff=32768 vocab=131072, MoE 8e top-2.

8 experts do not divide the 16-way model axis, so experts stay replicated
and tensor parallelism runs *inside* each expert (rule override)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="grok1_314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    n_experts=8,
    top_k=2,
    moe_d_ff=32768,
    rope_theta=10000.0,
    optimizer="adafactor",               # AdamW fp32 state (3.8TB) exceeds
                                         # one pod's HBM; see §Dry-run
    rule_overrides={"expert": None,      # 8 experts vs 16-way model axis
                    "exp_cap": "data",  # shard dispatch capacity instead
                    "kv_heads": None},
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    n_experts=4,
    top_k=2,
    moe_d_ff=128,
    capacity_factor=8.0,   # smoke: no token drops (decode-consistency tests)
    compute_dtype="float32",
    rule_overrides=None,
)


# §Perf-winning preset (EXPERIMENTS.md hillclimb B): shard-local MoE
# dispatch + collective-saving remat. RF 0.014 -> 0.198 when lowered on the
# expert-factored mesh (data=16, expert_ax=8, model=2) with
# rules {expert: expert_ax, heads/vocab: (expert_ax, model), exp_cap: data}.
OPTIMIZED = CONFIG.replace(
    moe_local_dispatch=True,
    remat="collectives",
)
