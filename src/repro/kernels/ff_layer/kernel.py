"""Decode-layer building-block programs: row-streamed matmul (with an
optional fused RMSNorm prologue) and the SwiGLU gate/up projection.

These are the node programs of the whole-layer ``decode_layer`` StreamGraph
(models/layers.py): QKV projection, attention out-projection, gate/up MLP
and down-projection are all instances of the two builders here. Unlike
``ff_matmul`` they keep k and n un-tiled (decode-layer operands are small:
one k-tile, one n-tile per word) so every program's word schedule is the
plain row-block sequence ``w -> (w, 0)``. That makes adjacent projections
*chain-fusable*: each node's output block schedule is exactly the next
node's input stream schedule, so ``compile_graph`` can keep the whole
residual stream in VMEM across the layer.

Norm weights and biases ride as ``BlockIn`` operands broadcast to
``block_m`` rows (not ``(1, n)``) so they stay ring-promotable inside a
fused chain — a pipe tile's sublane dim must be a multiple of 8.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pipe import Pipe
from repro.core.program import BlockIn, Stream, StreamProgram


def _rms(x, nw, eps):
    """Mirror models.layers.rmsnorm numerics exactly: f32 mean-square,
    rsqrt, scale by the (f32) weight, cast back to the input dtype."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    x32 = x32 * jax.lax.rsqrt(
        jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * nw.astype(jnp.float32)).astype(dt)


def build_matmul_program(m: int, n: int, k: int, *,
                         block_m: int = 8, norm: bool = False,
                         eps: float = 1e-6, dtype=jnp.float32,
                         b_dtype=None, out_dtype=None,
                         depth: int = 2, streams: int = 1,
                         name: str = "ff_layer_matmul") -> StreamProgram:
    """``out = maybe_rmsnorm(a) @ b`` with one word per ``block_m``-row
    block of ``a`` (k and n un-tiled). With ``norm=True`` the RMSNorm
    weight arrives as BlockIn ``nw`` of shape ``(block_m, k)`` — the
    caller broadcasts the ``(k,)`` weight to ``block_m`` identical rows."""
    assert m % block_m == 0, (m, block_m)
    b_dtype = b_dtype or dtype
    out_dtype = out_dtype or dtype

    def a_slicer(ctx, word):
        return ctx.ref("a").at[pl.ds(word * block_m, block_m), pl.ds(0, k)]

    def b_slicer(ctx, word):
        return ctx.ref("b").at[pl.ds(0, k), pl.ds(0, n)]

    def consumer(ctx):
        a = ctx.word("a")[...]
        if norm:
            a = _rms(a, ctx.ref("nw")[...], eps)
        acc = jnp.dot(a, ctx.word("b")[...],
                      preferred_element_type=jnp.float32)
        ctx.out[...] = acc.astype(out_dtype)

    inputs = [
        Stream("a", Pipe(tile=(block_m, k), dtype=dtype, depth=depth,
                         streams=streams), a_slicer,
               index=lambda w: (w, 0)),
        # the weight block is revisited every word: one HBM load, then the
        # ring serves it for the whole grid
        Stream("b", Pipe(tile=(k, n), dtype=b_dtype, depth=depth), b_slicer,
               index=lambda w: (0, 0)),
    ]
    if norm:
        inputs.append(BlockIn("nw", (block_m, k), lambda w: (0, 0),
                              dtype=jnp.float32))

    return StreamProgram(
        name=name,
        n_words=m // block_m,
        inputs=tuple(inputs),
        consumer=consumer,
        out_shape=(m, n),
        out_dtype=out_dtype,
        out_block=(block_m, n),
        out_index_map=lambda g: (g, 0),
    )


def build_swiglu_program(m: int, f: int, k: int, *,
                         block_m: int = 8, norm: bool = True,
                         eps: float = 1e-6, dtype=jnp.float32,
                         out_dtype=None, depth: int = 2,
                         streams: int = 1) -> StreamProgram:
    """``out = silu(maybe_rmsnorm(x) @ wg) * (maybe_rmsnorm(x) @ wu)`` —
    the gate/up half of the SwiGLU MLP as one word per row block, matching
    models.layers.mlp_apply with ``wi = concat([wg, wu], axis=1)``."""
    assert m % block_m == 0, (m, block_m)
    out_dtype = out_dtype or dtype

    def x_slicer(ctx, word):
        return ctx.ref("x").at[pl.ds(word * block_m, block_m), pl.ds(0, k)]

    def wg_slicer(ctx, word):
        return ctx.ref("wg").at[pl.ds(0, k), pl.ds(0, f)]

    def wu_slicer(ctx, word):
        return ctx.ref("wu").at[pl.ds(0, k), pl.ds(0, f)]

    def consumer(ctx):
        x = ctx.word("x")[...]
        if norm:
            x = _rms(x, ctx.ref("nw")[...], eps)
        g32 = jnp.dot(x, ctx.word("wg")[...],
                      preferred_element_type=jnp.float32)
        u32 = jnp.dot(x, ctx.word("wu")[...],
                      preferred_element_type=jnp.float32)
        ctx.out[...] = (jax.nn.silu(g32) * u32).astype(out_dtype)

    inputs = [
        Stream("x", Pipe(tile=(block_m, k), dtype=dtype, depth=depth,
                         streams=streams), x_slicer,
               index=lambda w: (w, 0)),
        Stream("wg", Pipe(tile=(k, f), dtype=dtype, depth=depth), wg_slicer,
               index=lambda w: (0, 0)),
        Stream("wu", Pipe(tile=(k, f), dtype=dtype, depth=depth), wu_slicer,
               index=lambda w: (0, 0)),
    ]
    if norm:
        inputs.append(BlockIn("nw", (block_m, k), lambda w: (0, 0),
                              dtype=jnp.float32))

    return StreamProgram(
        name="ff_layer_swiglu",
        n_words=m // block_m,
        inputs=tuple(inputs),
        consumer=consumer,
        out_shape=(m, f),
        out_dtype=out_dtype,
        out_block=(block_m, f),
        out_index_map=lambda g: (g, 0),
    )
