"""Paged-KV serving correctness: the paged decode path must be
token-for-token (and, against the contiguous ff path, *bitwise*) identical
to the dense-cache reference, and the block allocator must recycle without
leaks or external fragmentation.

Layers covered:
  * BlockAllocator unit tests — LIFO recycling, atomic out-of-blocks
    failure, no external fragmentation after random churn.
  * gather_indices layout — the row stream decodes back to the exact
    (block, k/v, offset, head) coordinates.
  * the registered ``paged_decode_attention`` StreamGraph vs. its XLA
    oracle (fused edge) and kernel-level bitwise parity vs. the contiguous
    ``ff_decode_attention`` at ``block_kv == page``.
  * model-level decode: dense cache vs. paged pool, xla and ff impls,
    bitwise logits equality over multiple steps (mixed lengths).
  * scheduler semantics: lockstep terminates in exactly
    ``max(remaining)`` steps per batch, EOS retires early and recycles
    blocks, the end-to-end serve bench keeps token parity.
  * ``pad_cache_to`` pads only declared sequence axes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.core.program import PipePolicy
from repro.launch import serve as serve_lib
from repro.launch import steps as steps_lib
from repro.runtime.paged_kv import (BlockAllocator, OutOfBlocks,
                                    PagedKVCache, gather_indices,
                                    paged_decode_attention)

KEY = jax.random.key(11)
ARCH = "qwen1_5_0p5b"
PAGE = 8


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------


def test_allocator_recycling():
    a = BlockAllocator(8)
    first = a.alloc(3)
    assert a.n_free == 5
    a.free(first)
    assert a.n_free == 8
    # LIFO: the most recently freed blocks are reissued first
    again = a.alloc(3)
    assert again == list(reversed(first))


def test_allocator_out_of_blocks_is_atomic():
    a = BlockAllocator(4)
    a.alloc(3)
    with pytest.raises(OutOfBlocks):
        a.alloc(2)
    # the failed allocation must not leak any blocks
    assert a.n_free == 1
    assert a.alloc(1) is not None


def test_allocator_no_external_fragmentation():
    """After arbitrary alloc/free churn, ANY request up to n_free must
    succeed — a free-list allocator over fixed-size blocks cannot
    externally fragment (waste is bounded by page-1 rows per request)."""
    rng = np.random.default_rng(0)
    a = BlockAllocator(32)
    held = []
    for _ in range(200):
        if held and rng.random() < 0.5:
            a.free(held.pop(rng.integers(len(held))))
        else:
            n = int(rng.integers(1, 5))
            if n <= a.n_free:
                held.append(a.alloc(n))
    if a.n_free:
        got = a.alloc(a.n_free)
        assert len(got) == len(set(got))
        assert a.n_free == 0


# ---------------------------------------------------------------------------
# Index layout
# ---------------------------------------------------------------------------


def test_gather_indices_layout():
    page, kvh, nb = 4, 3, 6
    bt = jnp.array([[5, 2], [0, nb]], jnp.int32)   # second row: sentinel
    idx = np.asarray(gather_indices(bt, page=page, kv_heads=kvh,
                                    n_blocks=nb))
    idx = idx.reshape(2, kvh, 2, 2, page)          # [B, KVH, npg, 2, page]
    for b, h, pg, which, off in np.ndindex(2, kvh, 2, 2, page):
        blk = min(int(bt[b, pg]), nb - 1)          # sentinel clips
        expect = ((blk * 2 + which) * page + off) * kvh + h
        assert idx[b, h, pg, which, off] == expect


# ---------------------------------------------------------------------------
# Graph + kernel parity
# ---------------------------------------------------------------------------


def test_paged_graph_fused_matches_oracle():
    from repro.kernels import registry as R
    spec = R.get_graph("paged_decode_attention")
    out, ref, err, compiled = R.run_graph_smoke(spec)
    assert err <= spec.tol, err
    assert any(e.mode == "fused" for e in compiled.plan.edges), \
        [(e.edge.label, e.rationale) for e in compiled.plan.edges]


def test_paged_kernel_bitwise_vs_contiguous():
    """Same pool dereferenced two ways: through the block-table stream
    graph and as a dense cache at block_kv == page. Identical tile order +
    identical f32 online softmax => bitwise-equal outputs, even with
    garbage in the masked tail (exp underflows to exactly 0)."""
    import repro
    b, h, kvh, d = 2, 8, 2, 64
    nb, page, npg = 12, 32, 5
    s = npg * page
    pool = jax.random.normal(KEY, (nb, 2, page, kvh, d), jnp.float32)
    perm = np.random.default_rng(3).permutation(nb)[:b * npg]
    bt = jnp.asarray(perm.reshape(b, npg), jnp.int32)
    lens = jnp.array([97, s], jnp.int32)
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (b, h, d),
                          jnp.float32)
    kv = pool[bt]
    k = kv[:, :, 0].reshape(b, s, kvh, d).transpose(0, 2, 1, 3)
    v = kv[:, :, 1].reshape(b, s, kvh, d).transpose(0, 2, 1, 3)
    pol = PipePolicy(mode="ff", depth=2, streams=1, interpret=True)
    cont = repro.ops.decode_attention(q, k, v, lens, block_kv=page,
                                      policy=pol)
    paged = paged_decode_attention(q, pool, bt, lens, policy=pol)
    assert np.array_equal(np.asarray(cont), np.asarray(paged))


# ---------------------------------------------------------------------------
# Model-level decode parity (mixed-length batch)
# ---------------------------------------------------------------------------


def _model_for(impl):
    cfg = smoke_config(ARCH).replace(remat="none", attn_impl=impl)
    if impl == "ff":
        cfg = cfg.replace(decode_block_kv=PAGE)
    from repro.models import build_model
    return build_model(cfg), cfg


@pytest.mark.parametrize("impl", ["xla", "ff"])
def test_decode_paged_vs_dense_bitwise(impl):
    model, cfg = _model_for(impl)
    params = model.init(KEY)
    policy = PipePolicy(mode="ff", interpret=True)
    diff = serve_lib.decode_parity_probe(model, params, cfg, policy,
                                         page=PAGE)
    assert diff == 0.0, diff


def _greedy(model, cfg, params, prompts, steps, *, paged):
    """Greedy tokens [B, steps] from a mixed-length prompt batch."""
    policy = PipePolicy(mode="ff", interpret=True)
    b = len(prompts)
    lens = np.array([len(p) for p in prompts], np.int32)
    p_max = int(lens.max())
    n_pages = -(-(p_max + steps) // PAGE)
    s_max = n_pages * PAGE
    toks = np.zeros((b, p_max), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    prefill = jax.jit(steps_lib.make_prefill_step(model, policy=policy))
    decode = jax.jit(steps_lib.make_decode_step(model, policy=policy))
    _, dense = prefill(params, {"tokens": jnp.asarray(toks)})
    if paged:
        kv = PagedKVCache(
            n_layers=cfg.n_layers, n_blocks=b * n_pages, page=PAGE,
            kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, n_slots=b,
            n_pages_max=n_pages, dtype=cfg.cdtype)
        for i in range(b):
            kv.admit(i, dense["k"][:, i], dense["v"][:, i], int(lens[i]),
                     s_max)
        kv.lengths[:] = lens - 1
        cache = kv.cache_view()
    else:
        cache = serve_lib.pad_cache_to(dense, p_max, s_max, 2)
    cur = jnp.asarray(toks[np.arange(b), lens - 1])
    lengths = jnp.asarray(lens - 1)
    out = []
    for _ in range(steps):
        cur, _, cache = decode(
            params, {"token": cur, "lengths": lengths}, cache)
        out.append(np.asarray(cur))
        lengths = lengths + 1
    return np.stack(out, axis=1)


def test_token_parity_paged_contiguous_oracle():
    """paged(ff) == contiguous(ff) == XLA oracle, token for token, on a
    mixed-length batch. The two ff paths are bitwise so their equality is
    exact by construction; the xla oracle decode must agree greedily."""
    rng = np.random.default_rng(5)
    cfg0 = smoke_config(ARCH)
    prompts = [rng.integers(1, cfg0.vocab, size=n).astype(np.int32)
               for n in (5, 12, 9)]
    steps = 6
    model_ff, cfg_ff = _model_for("ff")
    model_x, cfg_x = _model_for("xla")
    params = model_ff.init(KEY)   # identical params for both impls
    t_paged = _greedy(model_ff, cfg_ff, params, prompts, steps, paged=True)
    t_cont = _greedy(model_ff, cfg_ff, params, prompts, steps, paged=False)
    t_oracle = _greedy(model_x, cfg_x, params, prompts, steps, paged=False)
    np.testing.assert_array_equal(t_paged, t_cont)
    np.testing.assert_array_equal(t_paged, t_oracle)


# ---------------------------------------------------------------------------
# Scheduler semantics
# ---------------------------------------------------------------------------


def _xla_setup():
    model, cfg = _model_for("xla")
    params = model.init(KEY)
    policy = PipePolicy(mode="ff", interpret=True)
    return model, cfg, params, policy


def _mk_requests(budgets, prompt_lens, vocab, arrival=0.0):
    rng = np.random.default_rng(9)
    return [serve_lib.Request(
        i, arrival, rng.integers(1, vocab, size=n).astype(np.int32), m)
        for i, (n, m) in enumerate(zip(prompt_lens, budgets))]


def test_lockstep_terminates_exactly():
    """Satellite: the decode loop must run exactly max(remaining budget)
    steps per batch — no runaway to max_new + prompt_len, no extra step
    flipping each finished row."""
    model, cfg, params, policy = _xla_setup()
    reqs = _mk_requests([3, 5, 2, 2], [6, 9, 4, 7], cfg.vocab)
    m = serve_lib.run_lockstep(model, params, cfg, reqs, n_slots=2,
                               page=PAGE, eos_id=None, policy=policy)
    assert m["decode_steps"] == 5 + 2       # max per batch of two
    assert m["tokens"] == 3 + 5 + 2 + 2


def test_eos_retires_and_recycles():
    """EOS retirement: with eos_id set to a token the model actually
    emits, requests finish early and the paged scheduler's recycled
    blocks let the same pool serve the trace."""
    model, cfg, params, policy = _xla_setup()
    reqs = _mk_requests([8] * 4, [5, 7, 6, 8], cfg.vocab)
    base = serve_lib.run_continuous(model, params, cfg, reqs, n_slots=2,
                                    page=PAGE, eos_id=None, policy=policy)
    assert base["tokens"] == 32
    # find a token the model actually emits by decoding one step, then
    # re-run the trace with that token as EOS
    dec = jax.jit(steps_lib.make_decode_step(model, policy=policy))
    pre = jax.jit(steps_lib.make_prefill_step(model, policy=policy))
    toks = np.zeros((1, 8), np.int32)
    toks[0, :5] = reqs[0].prompt
    _, cache = pre(params, {"tokens": jnp.asarray(toks)})
    cache = serve_lib.pad_cache_to(cache, 8, 16, 2)
    nxt, _, _ = dec(params, {"token": jnp.asarray([reqs[0].prompt[-1]]),
                             "lengths": jnp.asarray([4])}, cache)
    eos = int(np.asarray(nxt)[0])
    early = serve_lib.run_continuous(model, params, cfg, reqs, n_slots=2,
                                     page=PAGE, eos_id=eos, policy=policy)
    assert early["tokens"] < base["tokens"]


def test_continuous_respects_pool_pressure():
    """A pool sized for ~one request at a time still serves the whole
    trace (admission waits for retirements instead of failing)."""
    model, cfg, params, policy = _xla_setup()
    reqs = _mk_requests([4] * 3, [5, 6, 7], cfg.vocab)
    m = serve_lib.run_continuous(model, params, cfg, reqs, n_slots=2,
                                 page=PAGE, eos_id=None, policy=policy,
                                 pool_blocks=2)
    assert m["tokens"] == 12


def test_serve_schedulers_token_parity():
    """Lockstep and paged continuous emit the same number of tokens per
    request over the same trace (greedy decode of the same model)."""
    model, cfg, params, policy = _xla_setup()
    reqs = _mk_requests([3, 4, 5], [5, 9, 6], cfg.vocab)
    ls = serve_lib.run_lockstep(model, params, cfg, reqs, n_slots=2,
                                page=PAGE, eos_id=None, policy=policy)
    pg = serve_lib.run_continuous(model, params, cfg, reqs, n_slots=2,
                                  page=PAGE, eos_id=None, policy=policy)
    assert ls["tokens"] == pg["tokens"] == 12


# ---------------------------------------------------------------------------
# pad_cache_to (satellite)
# ---------------------------------------------------------------------------


def test_pad_cache_to_pads_only_declared_axis():
    # head dim (axis 2) equals the prompt length — the old shape-matching
    # pad would corrupt it
    leaf = jnp.ones((2, 4, 4, 3))
    out = serve_lib.pad_cache_to({"k": leaf}, 4, 8, 1)
    assert out["k"].shape == (2, 8, 4, 3)
    # per-leaf axes: None leaves untouched
    cache = {"k": leaf, "state": jnp.ones((4, 4))}
    out = serve_lib.pad_cache_to(cache, 4, 8, {"k": 1, "state": None})
    assert out["k"].shape == (2, 8, 4, 3)
    assert out["state"].shape == (4, 4)


def test_pad_cache_to_requires_seq_dims():
    with pytest.raises(TypeError):
        serve_lib.pad_cache_to({"k": jnp.ones((2, 4))}, 4, 8, None)
    with pytest.raises(ValueError):
        serve_lib.pad_cache_to({"k": jnp.ones((2, 5))}, 4, 8, 1)
