"""Architecture + shape configuration schema.

One ``ArchConfig`` per assigned architecture (exact dims from the assignment
sheet) lives in ``repro.configs.<id>``. ``SHAPES`` defines the four assigned
input-shape cells; applicability per family follows the assignment rules
(long_500k only for sub-quadratic archs, decode only for archs with a
decoder).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None           # default d_model // n_heads
    qkv_bias: bool = False
    act: str = "swiglu"                       # swiglu | gelu
    norm: str = "rmsnorm"                     # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # MLA (deepseek)
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every_n: int = 0                     # zamba2: shared attn block cadence
    conv_width: int = 4

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    n_frames: int = 1500                      # stubbed conv-frontend output len

    # VLM
    n_patches: int = 256                      # stubbed ViT patch embeddings

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"                       # none | full | dots
    optimizer: str = "adamw"                  # adamw | adafactor

    # per-arch logical-rule overrides (e.g. grok: 8 experts don't divide the
    # 16-way model axis -> keep experts replicated, TP inside each expert)
    rule_overrides: Optional[Dict[str, object]] = None

    # implementation switches (hillclimb knobs)
    attn_impl: str = "xla"                    # xla | ff
    decode_block_kv: Optional[int] = None     # pin the ff decode-attention
                                              # KV tile (None = heuristic);
                                              # serving pins it to the paged
                                              # cache's page size so the
                                              # contiguous path is bitwise-
                                              # equal to the paged path
    layer_graph: bool = False                 # route dense-cache decode steps
                                              # through the whole-layer
                                              # decode_layer StreamGraph (one
                                              # planned multi-kernel program
                                              # per layer step)
    scan_impl: str = "xla"                    # xla | xla_tiled | ff
    scan_layers: bool = True                  # lax.scan over layer stack
    loss_chunk: int = 0                       # >1: chunked-vocab CE (no full
                                              # [B,S,V] f32 logits temp)
    scan_chunk: int = 64                      # GLA chunk length (hillclimb)
    moe_local_dispatch: bool = False          # per-data-shard MoE dispatch
                                              # (local scatter -> all-to-all)
    bf16_grads: bool = False                  # cast layer-boundary cotangents
                                              # to bf16 (halves bwd collective
                                              # and HBM bytes)
    unroll_layers: int = 0                    # >0: build only N unrolled layers
                                              # (cost-extraction variants)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to 128 so the table/logits shard on the model axis
        (standard padded-vocab practice; padded ids are never labels)."""
        return -(-self.vocab // 128) * 128

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("hybrid", "ssm")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-dec)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode
    # rule overrides applied when this shape is lowered (e.g. batch=1 decode
    # cannot shard batch; shard the KV-cache sequence instead)
    rule_overrides: Optional[Dict[str, object]] = None


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    # prefill emits a cache: shard its seq ("kv") over model so no device
    # holds a replicated 32k cache
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill",
                               rule_overrides={"kv": "model"}),
    # decode: cache seq sharded over model (kv head counts rarely divide 16)
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode",
                              rule_overrides={"kv": "model", "seq": None,
                                              "kv_heads": None}),
    # batch=1: nothing to DP; shard the long cache seq over data instead
    "long_500k": ShapeConfig(
        "long_500k", 524288, 1, "decode",
        rule_overrides={"batch": None, "kv": "data", "seq": None,
                        "state": None}),
}

ARCH_IDS = (
    "zamba2_2p7b",
    "starcoder2_15b",
    "qwen2_72b",
    "llama3_2_1b",
    "qwen1_5_0p5b",
    "grok1_314b",
    "deepseek_v2_lite_16b",
    "whisper_tiny",
    "internvl2_1b",
    "rwkv6_7b",
)


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules for which (arch x shape) cells run."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md)"
    return True, ""


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def smoke_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SMOKE
