"""Stateless synthetic LM data: batch(step) is a pure function of
(seed, step), so a restarted job regenerates the identical stream — the
bitwise-reproducible-resume property the fault-tolerance tests assert.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # modality stubs
    n_frames: int = 0
    n_patches: int = 0
    d_model: int = 0


def _rng(spec: SyntheticSpec, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([spec.seed, step, 0xF0D]))


def batch_at(spec: SyntheticSpec, step: int) -> Dict[str, np.ndarray]:
    """Markov-ish token stream (so loss is learnable, not pure noise)."""
    rng = _rng(spec, step)
    b, s = spec.global_batch, spec.seq_len
    base = rng.integers(0, spec.vocab, size=(b, 1), dtype=np.int32)
    drift = rng.integers(0, 7, size=(b, s), dtype=np.int32).cumsum(axis=1)
    tokens = ((base + drift) % spec.vocab).astype(np.int32)
    labels = np.roll(tokens, -1, axis=1).astype(np.int32)
    out = {"tokens": tokens, "labels": labels}
    if spec.n_frames:
        out["frames"] = rng.standard_normal(
            (b, spec.n_frames, spec.d_model)).astype(np.float32)
    if spec.n_patches:
        out["image_embeds"] = rng.standard_normal(
            (b, spec.n_patches, spec.d_model)).astype(np.float32)
    return out
