import os
import sys

# Tests run on the single real CPU device (the dry-run alone forces 512
# placeholder devices). Distributed tests spawn subprocesses with their own
# XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
