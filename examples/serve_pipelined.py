"""Batched serving example: continuous-batching greedy decode with separate
prefill/decode programs (the feed-forward model at the serving level —
prefill produces the KV-cache pipe, the decode loop consumes it), running
through the ``repro.ops`` stream kernels under a session policy.

The serving driver installs the mesh-tagged session
:class:`repro.PipePolicy` around the prefill/decode step bodies, so every
attention call inside the model resolves its pipe plan under the serving
mesh topology. This example shows the same two-layer API directly first —
``repro.ops`` + ``with repro.policy(...)`` — then runs the full driver.

Run:  PYTHONPATH=src python examples/serve_pipelined.py
"""

import jax
import jax.numpy as jnp

import repro
from repro.launch import serve as serve_mod


def decode_attention_demo():
    """One serving decode step through repro.ops: the KV cache is the pipe,
    flash-decode is the consumer. Policies come from the session context —
    no per-op mode/depth/streams keywords anywhere."""
    key = jax.random.key(0)
    b, h, d, s_kv = 2, 4, 64, 128
    q = jax.random.normal(key, (b, h, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s_kv, d),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s_kv, d),
                          jnp.float32)
    lengths = jnp.array([70, 128], jnp.int32)

    with repro.policy(mode="ref"):                 # pure-XLA oracle
        ref = repro.ops.decode_attention(q, k, v, lengths)
    with repro.policy(mode="ff"):                  # planner-sized pipes
        out = repro.ops.decode_attention(q, k, v, lengths)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"decode_attention via repro.ops: max|err| vs oracle = {err:.2e}")


if __name__ == "__main__":
    decode_attention_demo()
    # the full continuous-batching driver: --impl ff routes the model's
    # attention call sites through the same repro.ops kernels, with the
    # session policy installed (mesh-tagged) around the step bodies
    with repro.policy(mode="ff"):
        serve_mod.main(["--arch", "qwen1_5_0p5b", "--smoke", "--impl", "ff",
                        "--policy-mode", "ff", "--requests", "4",
                        "--prompt-len", "16", "--max-new", "8"])
