"""Shared building blocks for all model families.

Params are plain nested dicts of arrays. Each model module declares its
parameters as :class:`ParamSpec` trees, which give us three views for free:

  * ``init``      — materialized random params (smoke tests / real training)
  * ``abstract``  — ShapeDtypeStruct stand-ins (dry-run lowering, no alloc)
  * ``axes``      — logical sharding axes per leaf (runtime.sharding rules)

Attention/scan/matmul call sites go through ``repro.kernels`` wrappers with
an ``impl`` switch: "xla" (HLO-visible reference path — used when lowering
for the dry-run and on CPU) or "ff" (the feed-forward Pallas kernels — the
TPU fast path, validated in interpret mode).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.sharding import constrain

# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"          # "normal" | "zeros" | "ones" | "small"
    scale: Optional[float] = None  # override fan-in scale

    def initializer(self, key: jax.Array) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "small":
            return 0.01 * jax.random.normal(key, self.shape, self.dtype)
        # fan-in = product of all non-output dims, skipping the stacked layer
        # dim (a [d, heads, hd] projection must scale by 1/sqrt(d), not
        # 1/sqrt(heads) — the old shape[-2] rule exploded wide attention)
        dims = self.shape
        if self.axes and self.axes[0] == "layers":
            dims = dims[1:]
        fan_in = max(int(np.prod(dims[:-1])), 1) if len(dims) >= 2 \
            else dims[-1]
        scale = self.scale if self.scale is not None else 1.0 / math.sqrt(fan_in)
        return scale * jax.random.normal(key, self.shape, self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, key: jax.Array):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [s.initializer(k) for s, k in zip(leaves, keys)])


def abstract_params(specs):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec)


def param_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def param_count(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


# NOTE (§Perf it5, refuted): applying the norm scale in bf16 (f32 stats
# only) was tried to shrink boundary collectives; collective bytes did not
# move and HBM bytes **rose** 18% (lost fusion in the backward). Reverted to
# f32-internal norms.
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_apply(kind: str, x, p):
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


def norm_specs(kind: str, d: int) -> Dict[str, ParamSpec]:
    s = {"w": ParamSpec((d,), ("embed",), init="ones")}
    if kind == "layernorm":
        s["b"] = ParamSpec((d,), ("embed",), init="zeros")
    return s


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
         dim: Optional[int] = None) -> jnp.ndarray:
    """Rotary embedding. x: [..., S, H, D]; positions: [..., S]."""
    d = dim or x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs   # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                            # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:d]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if d < x.shape[-1]:
        rot = jnp.concatenate([rot, x[..., d:]], axis=-1)
    return rot.astype(x.dtype)


def sinusoidal_positions(s: int, d: int) -> jnp.ndarray:
    pos = np.arange(s)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), jnp.float32)


# ---------------------------------------------------------------------------
# Attention (GQA) — XLA reference path + kernel fast path
# ---------------------------------------------------------------------------


_Q_CHUNK = 1024


def _attention_xla_block(q, k, v, *, causal, q_offset, positions_q=None,
                         lengths=None) -> jnp.ndarray:
    b, s, h, d = q.shape
    kvh = k.shape[2]
    group = h // kvh
    qg = q.reshape(b, s, kvh, group, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    skv = k.shape[1]
    if causal:
        qpos = (positions_q if positions_q is not None
                else q_offset + jnp.arange(s))
        mask = qpos[:, None] >= jnp.arange(skv)[None, :]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    if lengths is not None:
        mask = jnp.arange(skv)[None, :] < lengths[:, None]      # [B, Skv]
        scores = jnp.where(mask[:, None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, v.shape[-1]).astype(q.dtype)


def attention_xla(q, k, v, *, causal: bool, positions_q=None,
                  lengths=None) -> jnp.ndarray:
    """q: [B,S,H,D]; k,v: [B,Skv,KVH,D] -> [B,S,H,D]. HLO-visible path.

    This is the roofline *baseline*: scores materialize through HBM exactly
    like the paper's baseline round-trips global memory. Long sequences are
    processed in q-chunks (scan) so the live score block stays bounded at
    [B, H, _Q_CHUNK, Skv] — the un-fused-but-not-insane baseline a careful
    XLA user would write.
    """
    b, s, h, d = q.shape
    if s <= _Q_CHUNK or s % _Q_CHUNK != 0 or positions_q is not None:
        return _attention_xla_block(q, k, v, causal=causal, q_offset=0,
                                    positions_q=positions_q, lengths=lengths)
    # statically unrolled q-chunks: a lax.map here would hide the chunk body
    # from cost_analysis (loop bodies are counted once — DESIGN.md §4)
    outs = []
    for i in range(s // _Q_CHUNK):
        qc = jax.lax.slice_in_dim(q, i * _Q_CHUNK, (i + 1) * _Q_CHUNK, axis=1)
        outs.append(_attention_xla_block(qc, k, v, causal=causal,
                                         q_offset=i * _Q_CHUNK,
                                         lengths=lengths))
    return jnp.concatenate(outs, axis=1)


def _session_kernel_policy(interpret: bool):
    """Derive the kernel policy from the session `repro.policy` context (so
    no-touch A/B runs reach model code), pinning only what the layer
    contract fixes; modes the attention kernels don't speak (e.g.
    chunk_scan's "xla") fall back to "ff". "autotune" passes through — the
    serve/train ``--policy-mode autotune`` path and the plan service
    (record/replay through the PlanDB lookup chain) depend on it."""
    from repro.core.program import current_policy
    pol = current_policy()
    if pol.mode not in ("ff", "baseline", "ref", "autotune"):
        pol = pol.replace(mode="ff")
    return pol.replace(interpret=interpret)


def _session_scan_policy(cfg_impl: str):
    """Scan-kernel policy: the model config pins the default impl, but an
    explicit session mode override (anything but the "ff" session default)
    wins — so `with repro.policy(mode="baseline")` A/B runs reach the
    chunk_scan call sites too. To force pipelined scans by default, set
    cfg.scan_impl="ff" rather than a session policy."""
    from repro.core.program import current_policy
    pol = current_policy()
    return pol.replace(mode=pol.mode if pol.mode != "ff" else cfg_impl)


def attention_op(q, k, v, *, causal: bool, impl: str = "xla",
                 lengths=None, interpret: bool = True) -> jnp.ndarray:
    """Dispatch between the XLA path and the ff_attention Pallas kernel."""
    if impl == "xla":
        return attention_xla(q, k, v, causal=causal, lengths=lengths)
    from repro.kernels.ff_attention import attention as ff_attn
    b, s, h, d = q.shape
    kvh = k.shape[2]
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kh = k.transpose(0, 2, 1, 3).reshape(b * kvh, k.shape[1], d)
    vh = v.transpose(0, 2, 1, 3).reshape(b * kvh, v.shape[1], d)
    block_q = min(128, max(8, s))
    out = ff_attn(qh, kh, vh, kv_groups=h // kvh, causal=causal,
                  block_q=block_q, block_kv=128,
                  policy=_session_kernel_policy(interpret))
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


def decode_attention_op(q, k, v, lengths, *, impl: str = "xla",
                        interpret: bool = True,
                        block_kv: Optional[int] = None) -> jnp.ndarray:
    """q: [B,H,D] one token; k,v: [B,Skv,KVH,D] cache; lengths: [B].
    ``block_kv`` pins the ff KV tile (serving pins it to the paged cache's
    page size for bitwise parity); None picks the traffic heuristic."""
    if impl == "xla":
        out = attention_xla(q[:, None], k, v, causal=False, lengths=lengths)
        return out[:, 0]
    from repro.kernels.ff_decode_attention import decode_attention as ff_dec
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    # the kernel streams whole KV tiles: round the cache up to the block
    # (rows past `lengths` are masked inside the kernel, so zero-padding
    # is free of numerics). For unpinned block_kv pick the tile that
    # minimizes padded traffic (skv=130 streams 160 rows at block 32, not
    # 256 at block 128), preferring larger tiles on ties (fewer DMAs).
    skv = k.shape[1]
    if block_kv is None:
        if skv <= 128:
            block_kv = -(-skv // 8) * 8
        else:
            block_kv = min((128, 64, 32),
                           key=lambda blk: (-(-skv // blk) * blk, -blk))
    pad = -skv % block_kv
    if pad:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return ff_dec(q, kh, vh, lengths, block_kv=block_kv,
                  policy=_session_kernel_policy(interpret))


def paged_decode_attention_op(q, kv_pool, block_tables, lengths, *,
                              impl: str = "xla",
                              interpret: bool = True) -> jnp.ndarray:
    """Decode attention through a paged KV pool (continuous batching).

    q: [B,H,D] one token; kv_pool: [nb, 2, page, KVH, D] (one layer's
    block pool); block_tables: [B, n_pages] (entries >= nb are sentinels);
    lengths: [B] (0 = inactive slot). "xla" dereferences the table densely;
    "ff" runs the fused gather->attention StreamGraph.
    """
    if impl == "xla":
        nb, _, page, kvh, d = kv_pool.shape
        b = q.shape[0]
        npg = block_tables.shape[-1]
        bt = jnp.clip(block_tables.astype(jnp.int32), 0, nb - 1)
        kv = kv_pool[bt]                  # [B, npg, 2, page, KVH, D]
        k = kv[:, :, 0].reshape(b, npg * page, kvh, d)
        v = kv[:, :, 1].reshape(b, npg * page, kvh, d)
        out = attention_xla(q[:, None], k, v, causal=False, lengths=lengths)
        return out[:, 0]
    from repro.runtime.paged_kv import paged_decode_attention
    return paged_decode_attention(q, kv_pool, block_tables, lengths,
                                  policy=_session_kernel_policy(interpret))


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_specs(d: int, f: int, act: str) -> Dict[str, ParamSpec]:
    s = {"wo": ParamSpec((f, d), ("mlp", "embed"))}
    if act == "swiglu":
        s["wi"] = ParamSpec((d, 2 * f), ("embed", "mlp"))
    else:
        s["wi"] = ParamSpec((d, f), ("embed", "mlp"))
        s["bi"] = ParamSpec((f,), ("mlp",), init="zeros")
        s["bo"] = ParamSpec((d,), ("embed",), init="zeros")
    return s


def mlp_apply(p, x, act: str) -> jnp.ndarray:
    dt = x.dtype
    if act == "swiglu":
        gate_up = x @ p["wi"].astype(dt)
        gate, up = jnp.split(gate_up, 2, axis=-1)
        h = jax.nn.silu(gate) * up
        return h @ p["wo"].astype(dt)
    h = x @ p["wi"].astype(dt) + p["bi"].astype(dt)
    h = jax.nn.gelu(h)
    return h @ p["wo"].astype(dt) + p["bo"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------


@jax.custom_vjp
def bf16_grad_barrier(x):
    """Identity whose cotangent is cast to bf16: placed between the (f32)
    loss and the decoder stack so every backward all-reduce below runs in
    bf16 — halves TP-boundary collective bytes (§Perf 'bf16 grads')."""
    return x


def _bgb_fwd(x):
    return x, None


def _bgb_bwd(_, ct):
    return (ct.astype(jnp.bfloat16).astype(ct.dtype)
            if ct.dtype == jnp.float32 else ct,)


# NOTE: casting f32->bf16->f32 keeps dtypes consistent for jax while
# quantizing the cotangent mantissa; XLA then propagates the cheap form.
bf16_grad_barrier.defvjp(_bgb_fwd, _bgb_bwd)


@jax.custom_vjp
def bf16_grad_cast(x):
    """Identity fwd; bwd converts the cotangent to true bf16 (dtype change).
    Valid where the primal is bf16 (cotangent dtype must match primal)."""
    return x


def _bgc_fwd(x):
    return x, jnp.zeros((0,), x.dtype)    # dtype token (valid JAX residual)


def _bgc_bwd(tok, ct):
    return (ct.astype(tok.dtype),)


bf16_grad_cast.defvjp(_bgc_fwd, _bgc_bwd)


def embed_specs(vocab: int, d: int) -> ParamSpec:
    return ParamSpec((vocab, d), ("vocab", "embed"), scale=0.02)


def embed_lookup(table: jnp.ndarray, tokens: jnp.ndarray,
                 compute_dtype) -> jnp.ndarray:
    out = jnp.take(table, tokens, axis=0).astype(compute_dtype)
    return constrain(out, ("batch", "seq", "embed"))


def unembed_logits(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """x: [B,S,D] -> logits [B,S,V] (bf16, sharded batch x vocab)."""
    logits = x @ table.T.astype(x.dtype)
    return constrain(logits, ("batch", "seq", "vocab"))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 1e-4) -> jnp.ndarray:
    """Mean token CE in f32, with a z-loss regularizer (stabilizes bf16)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - gold
    if z_loss:
        loss = loss + z_loss * lse ** 2
    return jnp.mean(loss)


def chunked_unembed_loss(x: jnp.ndarray, table: jnp.ndarray,
                         labels: jnp.ndarray, n_chunks: int,
                         z_loss: float = 1e-4) -> jnp.ndarray:
    """CE without materializing the full [B,S,V] logits: the unembed matmul
    + softmax run per sequence chunk (statically unrolled so cost_analysis
    sees every chunk). Cuts the dominant train-step temp (f32 logits) by
    ``n_chunks`` — §Perf iteration 'chunked-vocab loss'."""
    b, s, d = x.shape
    assert s % n_chunks == 0, (s, n_chunks)
    cs = s // n_chunks
    total = jnp.zeros((), jnp.float32)
    wt = table.T.astype(x.dtype)
    for i in range(n_chunks):
        xc = jax.lax.slice_in_dim(x, i * cs, (i + 1) * cs, axis=1)
        lc = jax.lax.slice_in_dim(labels, i * cs, (i + 1) * cs, axis=1)
        logits = constrain(xc @ wt, ("batch", "seq", "vocab"))
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        piece = lse - gold
        if z_loss:
            piece = piece + z_loss * lse ** 2
        total = total + jnp.sum(piece)
    return total / (b * s)


# ---------------------------------------------------------------------------
# StreamGraph workload: attention -> out-projection
# ---------------------------------------------------------------------------
#
# The transformer block's hottest fusion opportunity above single kernels:
# flash attention writes [BH, S, D] q-blocks in q-major order, and the out-
# projection matmul streams exactly those (block_q, d) tiles as its A
# operand — so the attention output can live in a VMEM ring inside one
# fused pallas_call instead of round-tripping HBM between two kernels
# (repro.core.graph decides per edge; a mismatched block_q stages instead).


def build_attention_proj_graph(*, bh: int = 2, s: int = 256, d: int = 64,
                               d_out: int = 256, causal: bool = True,
                               dtype=jnp.float32, depth: int = 2,
                               streams: int = 1, block_q: int = 128):
    """Declare the attention→out-projection StreamGraph at one shape point.

    The projection's M tile is pinned to ``block_q`` so the edge is fusable
    when the attention output schedule lines up; ``block_q`` is the joint
    tuner's shared-tile axis.
    """
    from repro.core.graph import GraphEdge, GraphNode, StreamGraph
    from repro.kernels.ff_attention.kernel import build_program as attn_prog
    from repro.kernels.ff_attention.ops import attention_workload
    from repro.kernels.ff_matmul.kernel import build_program as matmul_prog
    from repro.kernels.ff_matmul.ops import matmul_workload

    block = (block_q, min(128, d_out), d)
    attn = attn_prog(bh, s, s, d, block_q=block_q, block_kv=128,
                     causal=causal, dtype=dtype, depth=depth, streams=streams)
    proj = matmul_prog(bh * s, d_out, d, block=block, dtype=dtype,
                       depth=depth, streams=streams)
    w_a, t_a = attention_workload(bh, s, d, causal=causal, block_q=block_q,
                                  dtype=dtype)
    w_p, t_p = matmul_workload(bh * s, d_out, d, block, dtype)
    return StreamGraph(
        name="attention_proj",
        nodes=(
            GraphNode("attn", attn, workload=w_a, plan_tile=t_a),
            GraphNode("proj", proj, workload=w_p, plan_tile=t_p),
        ),
        edges=(
            GraphEdge("attn", "proj", "a", reshape=(bh * s, d)),
        ),
    )


def _attention_proj_inputs(key):
    """Operands in CompiledGraph.arg_names order:
    (attn.q, attn.k, attn.v, proj.b)."""
    # d_out = 2 N tiles: the projection re-reads each attention block
    # once per N tile, so the fused ring saves the re-streams too
    bh, s, d, d_out = 2, 256, 64, 256
    q = 0.3 * jax.random.normal(key, (bh, s, d), jnp.float32)
    k = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (bh, s, d),
                                jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, s, d),
                          jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 3), (d, d_out),
                          jnp.float32) / jnp.sqrt(d)
    return (q, k, v, w)


def _attention_proj_ref(q, k, v, w):
    bh, s, d = q.shape
    scores = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None], scores, -1e30)
    attn = jnp.einsum("bst,btd->bsd", jax.nn.softmax(scores, axis=-1),
                      v.astype(jnp.float32))
    return (attn.reshape(bh * s, d) @ w.astype(jnp.float32)).astype(q.dtype)


def _attention_proj_unfused(q, k, v, w):
    """Attention then projection as two separate repro.ops calls — the
    [BH, S, D] intermediate round-trips HBM (the BENCH_graph baseline).
    The projection is pinned to the graph's tile so the comparison
    isolates the lowering, not the tiling."""
    import repro

    bh, s, d = q.shape
    attn = repro.ops.attention(q, k, v, causal=True)
    return repro.ops.matmul(attn.reshape(bh * s, d), w,
                            block=(128, 128, d))


def attention_proj(q, k, v, w, *, causal: bool = True,
                   policy=None) -> jnp.ndarray:
    """Causal attention → out-projection through the fused StreamGraph, at
    the caller's shapes.

    q/k/v: [BH, S, D]; w: [D, D_out]. Returns [BH*S, D_out].

    Unlike ``run_graph`` (fixed smoke shapes), this entrypoint resolves the
    joint graph plan at the call site's shapes and records the site for the
    plan-service sweep — mirroring ``paged_decode_attention``.
    """
    from repro.core import autotune
    from repro.core import graph as graphlib
    from repro.core.program import current_policy

    policy = current_policy() if policy is None else policy
    if policy.mode == "ref":
        return _attention_proj_ref(q, k, v, w)
    bh, s, d = q.shape
    d_out = w.shape[1]

    def build(depth=2, streams=1, **tk):
        return build_attention_proj_graph(
            bh=bh, s=s, d=d, d_out=d_out, causal=causal, dtype=q.dtype,
            depth=depth, streams=streams, **tk)

    g0 = build()
    wl, tile = graphlib.graph_workload(g0)
    sig = graphlib.graph_signature(g0)

    def runner(tk, depth, streams):
        cg = graphlib.compile_graph(
            build(depth=depth, streams=streams, **dict(tk)),
            policy=policy.replace(mode="ff", depth=depth, streams=streams))
        return lambda: cg(q, k, v, w)

    choice = autotune.resolve_graph(
        "attention_proj", policy, workload=wl, tile=tile,
        dtype=q.dtype, signature=sig,
        workload_fn=lambda tk: graphlib.graph_workload(build(**dict(tk))),
        runner=None if autotune.has_tracers(q, k, v, w) else runner,
        site={"bh": bh, "s": s, "d": d, "d_out": d_out,
              "causal": bool(causal)},
        site_dynamic=("bh", "s"),
        tile_options=({"block_q": 64},))
    # compiled fresh per call (trace-scoped closures must not be reused)
    mode = "ff" if policy.mode == "autotune" else policy.mode
    cg = graphlib.compile_graph(
        build(depth=choice.depth, streams=choice.streams,
              **dict(choice.tile_kwargs)),
        policy=policy.replace(mode=mode, depth=choice.depth,
                              streams=choice.streams))
    return cg(q, k, v, w)


def _attention_proj_sweep_inputs(key, site):
    """Rebuild attention_proj operands at a recorded call-site shape
    (plan sweep)."""
    bh, s = int(site["bh"]), int(site["s"])
    d, d_out = int(site["d"]), int(site["d_out"])
    dt = jnp.dtype(site.get("dtype", "float32"))
    q = 0.3 * jax.random.normal(key, (bh, s, d), dt)
    k = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (bh, s, d), dt)
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, s, d), dt)
    w = jax.random.normal(jax.random.fold_in(key, 3), (d, d_out),
                          dt) / jnp.sqrt(d)
    kwargs = {"causal": bool(site.get("causal", True))}
    return (q, k, v, w), kwargs


def _register_attention_proj_graph():
    from repro.kernels.registry import register_graph

    register_graph(
        name="attention_proj",
        build=build_attention_proj_graph,
        make_inputs=_attention_proj_inputs,
        ref=_attention_proj_ref,
        unfused=_attention_proj_unfused,
        tile_options=({"block_q": 64},),
        tol=5e-4,
        doc="flash attention -> out-projection matmul; the [BH,S,D] "
            "intermediate stays in a VMEM ring when block_q tiles match",
        # plan-service sweep: resolve at call-site shapes through the real
        # entrypoint, not run_graph's fixed smoke point
        op=attention_proj,
        sweep_inputs=_attention_proj_sweep_inputs,
    )


_register_attention_proj_graph()
