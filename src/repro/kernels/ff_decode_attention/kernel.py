"""Feed-forward decode attention as a StreamProgram: one new token vs. a
long KV cache.

The decode step is the paper's favourable case par excellence: a huge,
perfectly *regular* stream (the KV cache) consumed by a tiny reduction with
a loop-carried softmax state. The cache stream is DLCD-free, so the memory
kernel prefetches KV tiles at full pipe depth while the consumer folds the
online softmax — the whole kernel runs at HBM bandwidth (roofline-memory
bound), which is exactly what the roofline table shows for decode cells.

Layout: q is [B, KVH, G, D] (G = padded query-head group per KV head, GQA),
cache k/v are [B, KVH, S, D], ``lengths[B]`` is scalar-prefetched and gives
the live cache prefix. Grid: 1-D over (b*kvh, kv_block), kv innermost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pipe import Pipe
from repro.core.program import BlockIn, ScalarIn, ScratchSpec, Stream, \
    StreamProgram, compile_program

_NEG_INF = -1e30


def build_program(b: int, kvh: int, g_pad: int, s: int, d: int, *,
                  block_kv: int = 128, dtype=jnp.float32, k_dtype=None,
                  v_dtype=None, out_dtype=None,
                  depth: int = 2, streams: int = 1) -> StreamProgram:
    """Declare the decode-attention stream program at one shape point.
    ``dtype`` is the q/out element type; ``k_dtype``/``v_dtype`` (default
    ``dtype``) size their own cache pipe edges."""
    assert s % block_kv == 0, (s, block_kv)
    nkv = s // block_kv
    scale = 1.0 / (d ** 0.5)
    out_dtype = out_dtype or dtype
    k_spec = Pipe(tile=(block_kv, d), dtype=k_dtype or dtype, depth=depth,
                  streams=streams)
    v_spec = Pipe(tile=(block_kv, d), dtype=v_dtype or dtype, depth=depth,
                  streams=streams)

    def kv_slicer(name):
        def f(ctx, word):
            w_kj = word % nkv
            w_bh = word // nkv
            return ctx.ref(name).at[w_bh // kvh, w_bh % kvh,
                                    pl.ds(w_kj * block_kv, block_kv), :]
        return f

    def consumer(ctx):
        kj = ctx.g % nkv
        b_idx = (ctx.g // nkv) // kvh
        length = ctx.ref("lengths")[b_idx]
        m_sc, l_sc = ctx.scratch("m"), ctx.scratch("l")
        acc = ctx.scratch("acc")

        @pl.when(kj == 0)
        def _():
            m_sc[...] = jnp.full_like(m_sc, _NEG_INF)
            l_sc[...] = jnp.zeros_like(l_sc)
            acc[...] = jnp.zeros_like(acc)

        kv_start = kj * block_kv

        @pl.when(kv_start < length)
        def _():
            q = ctx.ref("q")[0, 0]                     # [g_pad, d]
            k = ctx.word("k")[...]                     # [bkv, d]
            v = ctx.word("v")[...]                     # [bkv, d]
            s_ = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale   # [g_pad, bkv]
            cols = kv_start + jax.lax.broadcasted_iota(
                jnp.int32, (g_pad, block_kv), 1)
            s_ = jnp.where(cols < length, s_, _NEG_INF)
            m_prev = m_sc[:, :1]
            m_new = jnp.maximum(m_prev, jnp.max(s_, axis=1, keepdims=True))
            p = jnp.exp(s_ - m_new)
            alpha = jnp.exp(m_prev - m_new)
            l_sc[...] = jnp.broadcast_to(
                l_sc[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True),
                l_sc.shape)
            acc[...] = acc[...] * alpha + jnp.dot(
                p.astype(v.dtype), v, preferred_element_type=jnp.float32)
            m_sc[...] = jnp.broadcast_to(m_new, m_sc.shape)

        @pl.when(kj == nkv - 1)
        def _():
            l = l_sc[:, :1]
            l = jnp.where(l == 0.0, 1.0, l)
            ctx.out[0, 0] = (acc[...] / l).astype(out_dtype)

    q_index_map = lambda g, lens: ((g // nkv) // kvh, (g // nkv) % kvh, 0, 0)
    return StreamProgram(
        name="ff_decode_attention",
        n_words=b * kvh * nkv,
        inputs=(
            ScalarIn("lengths"),
            BlockIn("q", (1, 1, g_pad, d), q_index_map, dtype=dtype),
            # kv block schedule in the pipe's (block_kv, d) blocking of the
            # row-flattened [B*KVH*S, d] cache view (a fused producer edge
            # declares reshape=(b*kvh*s, d)): the word order is exactly
            # (b, h, kj)-major, so word w reads row block w
            Stream("k", k_spec, kv_slicer("k"), index=lambda w: (w, 0)),
            Stream("v", v_spec, kv_slicer("v"), index=lambda w: (w, 0)),
        ),
        consumer=consumer,
        out_shape=(b, kvh, g_pad, d),
        out_dtype=out_dtype,
        out_block=(1, 1, g_pad, d),
        out_index_map=q_index_map,
        scratch=(
            ScratchSpec("m", (g_pad, 128), jnp.float32),
            ScratchSpec("l", (g_pad, 128), jnp.float32),
            ScratchSpec("acc", (g_pad, d), jnp.float32),
        ),
    )


def build_paged_program(b: int, kvh: int, g_pad: int, n_pages: int,
                        page: int, d: int, *, dtype=jnp.float32,
                        kv_dtype=None, out_dtype=None,
                        depth: int = 2, streams: int = 1) -> StreamProgram:
    """Paged-KV decode attention: the consumer half of the
    ``paged_decode_attention`` StreamGraph.

    The KV operand is the *gathered* row stream ``[B*KVH*n_pages*2*page, d]``
    produced by an ``ff_gather`` node walking the block table — each word is
    one page's K rows followed by its V rows (a merged ``(2*page, d)`` tile),
    so the producer's 8-row DMA bundles line up word-for-word with this
    stream and the edge fuses into a single ``pallas_call``. The online
    softmax is *identical* to :func:`build_program` at ``block_kv == page``
    (same tile order, same f32 accumulation), which is what makes the paged
    path bitwise-equal to the contiguous cache path.
    """
    scale = 1.0 / (d ** 0.5)
    out_dtype = out_dtype or dtype
    kv_spec = Pipe(tile=(2 * page, d), dtype=kv_dtype or dtype, depth=depth,
                   streams=streams)

    def kv_slicer(ctx, word):
        return ctx.ref("kv").at[pl.ds(word * 2 * page, 2 * page), :]

    def consumer(ctx):
        kj = ctx.g % n_pages
        b_idx = (ctx.g // n_pages) // kvh
        length = ctx.ref("lengths")[b_idx]
        m_sc, l_sc = ctx.scratch("m"), ctx.scratch("l")
        acc = ctx.scratch("acc")

        @pl.when(kj == 0)
        def _():
            m_sc[...] = jnp.full_like(m_sc, _NEG_INF)
            l_sc[...] = jnp.zeros_like(l_sc)
            acc[...] = jnp.zeros_like(acc)

        kv_start = kj * page

        @pl.when(kv_start < length)
        def _():
            q = ctx.ref("q")[0, 0]                     # [g_pad, d]
            kv = ctx.word("kv")[...]                   # [2*page, d]
            k = kv[:page]
            v = kv[page:]
            s_ = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale   # [g_pad, page]
            cols = kv_start + jax.lax.broadcasted_iota(
                jnp.int32, (g_pad, page), 1)
            # rows past `length` (zero padding or stale recycled-block
            # contents) mask to -inf, so their exp underflows to exactly
            # 0.0 — recycled garbage cannot perturb even the last bit
            s_ = jnp.where(cols < length, s_, _NEG_INF)
            m_prev = m_sc[:, :1]
            m_new = jnp.maximum(m_prev, jnp.max(s_, axis=1, keepdims=True))
            p = jnp.exp(s_ - m_new)
            alpha = jnp.exp(m_prev - m_new)
            l_sc[...] = jnp.broadcast_to(
                l_sc[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True),
                l_sc.shape)
            acc[...] = acc[...] * alpha + jnp.dot(
                p.astype(v.dtype), v, preferred_element_type=jnp.float32)
            m_sc[...] = jnp.broadcast_to(m_new, m_sc.shape)

        @pl.when(kj == n_pages - 1)
        def _():
            l = l_sc[:, :1]
            l = jnp.where(l == 0.0, 1.0, l)
            ctx.out[0, 0] = (acc[...] / l).astype(out_dtype)

    q_index_map = lambda g, lens: ((g // n_pages) // kvh,
                                   (g // n_pages) % kvh, 0, 0)
    return StreamProgram(
        name="ff_paged_decode_attention",
        n_words=b * kvh * n_pages,
        inputs=(
            ScalarIn("lengths"),
            BlockIn("q", (1, 1, g_pad, d), q_index_map, dtype=dtype),
            # word w reads row block w of the gathered [n_words*2*page, d]
            # stream — the identity schedule an ff_gather producer writes,
            # so check_fusion legalizes the edge with wpb=1
            Stream("kv", kv_spec, kv_slicer, index=lambda w: (w, 0)),
        ),
        consumer=consumer,
        out_shape=(b, kvh, g_pad, d),
        out_dtype=out_dtype,
        out_block=(1, 1, g_pad, d),
        out_index_map=q_index_map,
        scratch=(
            ScratchSpec("m", (g_pad, 128), jnp.float32),
            ScratchSpec("l", (g_pad, 128), jnp.float32),
            ScratchSpec("acc", (g_pad, d), jnp.float32),
        ),
    )


@functools.partial(
    jax.jit,
    static_argnames=("block_kv", "depth", "streams", "interpret"))
def decode_attention_ff(
    q: jnp.ndarray,           # [B, KVH, G_pad, D]
    k: jnp.ndarray,           # [B, KVH, S, D]
    v: jnp.ndarray,           # [B, KVH, S, D]
    lengths: jnp.ndarray,     # [B] int32
    *,
    block_kv: int = 128,
    depth: int = 2,
    streams: int = 1,
    interpret: bool = True,
) -> jnp.ndarray:
    b, kvh, g_pad, d = q.shape
    _, _, s, _ = k.shape
    program = build_program(b, kvh, g_pad, s, d, block_kv=block_kv,
                            dtype=q.dtype, k_dtype=k.dtype, v_dtype=v.dtype,
                            depth=depth, streams=streams)
    return compile_program(program, interpret=interpret)(lengths, q, k, v)
