"""Straggler detection + mitigation policy.

At 1000+ nodes, the slowest participant sets the step time for synchronous
SPMD. The watchdog keeps a robust median/MAD model of per-step durations:
an observation is an outlier when it exceeds ``median + mad_factor *
1.4826 * MAD`` (1.4826 scales the MAD to a sigma-equivalent for normal
noise). When the MAD is 0 — every sample identical, the degenerate window
a fresh job starts with — the model falls back to the multiplicative
``slow_factor * median`` threshold. Persistent outliers trigger a
mitigation action:

  "none"            within tolerance
  "rebalance"       transient slowness: shrink that host's data shard
                    (the :class:`BatchRebalancer` hook — a smaller shard
                    is a smaller local word schedule, so the host's pipes
                    re-plan at the shrunk shape)
  "replace"         persistent: promote a hot spare, evict the host, and
                    elastic-remesh (runtime.elastic) from checkpoint

The policy is pure bookkeeping (host-side), so it is fully unit-testable
without hardware; the trainer wires `observe_step` around its step timer
and `mitigate` makes the returned actions real through the hooks.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence

# MAD -> sigma-equivalent scale for normally distributed noise
_MAD_SCALE = 1.4826


@dataclasses.dataclass
class StragglerConfig:
    window: int = 50
    slow_factor: float = 1.5       # x median step time = outlier (MAD == 0)
    mad_factor: float = 5.0        # sigma-equivalents above median (MAD > 0)
    tolerate: int = 3              # consecutive outliers before rebalance
    evict_after: int = 10          # consecutive outliers before replace
    hot_spares: int = 2


def _median(vals: Sequence[float]) -> float:
    """True median: mean of the two middle elements for even lengths."""
    s = sorted(vals)
    n = len(s)
    if n == 0:
        return 0.0
    mid = n // 2
    if n % 2:
        return float(s[mid])
    return float((s[mid - 1] + s[mid]) / 2.0)


class BatchRebalancer:
    """Per-host data-shard shares, shrinkable when a host straggles.

    ``shares[host]`` is the number of batch rows (or micro-shards) the host
    owns. :meth:`shrink` halves a slow host's share (never below
    ``min_share``) and redistributes nothing — synchronous SPMD means the
    freed rows pad the global batch's other shards implicitly; what matters
    for the stream stack is that the *local* workload changed, so the
    ``replan`` hook re-plans the host's pipes at the shrunk shape (e.g. by
    running the kernel once at the new local batch under its mesh-tagged
    policy, which repopulates the planner/autotune caches at the new
    workload key).
    """

    def __init__(self, shares: Dict[str, int], *, min_share: int = 1,
                 replan: Optional[Callable[[str, int], Any]] = None):
        self.shares = dict(shares)
        self.min_share = int(min_share)
        self.replan = replan
        self.shrunk: Dict[str, int] = {}     # host -> number of shrinks
        self.last_replan: Dict[str, Any] = {}

    def shrink(self, host: str) -> int:
        """Halve ``host``'s share (floor ``min_share``); re-plan via the
        hook when the share actually changed. Returns the new share."""
        old = self.shares.get(host)
        if old is None:
            return 0
        new = max(old // 2, self.min_share)
        if new != old:
            self.shares[host] = new
            self.shrunk[host] = self.shrunk.get(host, 0) + 1
            if self.replan is not None:
                self.last_replan[host] = self.replan(host, new)
        return new

    def drop(self, host: str) -> None:
        self.shares.pop(host, None)

    def total(self) -> int:
        return sum(self.shares.values())


class StragglerWatchdog:
    def __init__(self, cfg: StragglerConfig, hosts: List[str],
                 rebalancer: Optional[BatchRebalancer] = None,
                 on_replace: Optional[Callable[[str], Any]] = None):
        self.cfg = cfg
        self.hosts = list(hosts)
        self.spares: List[str] = [f"spare_{i}" for i in range(cfg.hot_spares)]
        self._times: Dict[str, Deque[float]] = {
            h: deque(maxlen=cfg.window) for h in hosts}
        self._strikes: Dict[str, int] = {h: 0 for h in hosts}
        self.evicted: List[str] = []
        self.rebalancer = rebalancer
        self.on_replace = on_replace
        self.mitigations: List[Dict[str, Any]] = []   # audit log of actions

    def _all_samples(self) -> List[float]:
        return [t for dq in self._times.values() for t in dq]

    def _threshold(self) -> float:
        """Outlier threshold of the current window: median + k*MAD
        (sigma-scaled), falling back to ``slow_factor * median`` when the
        MAD is 0 (degenerate window — all samples identical)."""
        samples = self._all_samples()
        med = _median(samples)
        if med <= 0:
            return 0.0
        mad = _median([abs(t - med) for t in samples])
        if mad > 0:
            return med + self.cfg.mad_factor * _MAD_SCALE * mad
        return self.cfg.slow_factor * med

    def observe_step(self, host_times: Dict[str, float]) -> Dict[str, str]:
        """Feed per-host step durations; returns {host: action}."""
        actions: Dict[str, str] = {}
        for h, t in host_times.items():
            if h not in self._times:
                continue
            self._times[h].append(t)
        thr = self._threshold()
        for h, t in host_times.items():
            if h not in self._times:
                continue
            if thr > 0 and t > thr:
                self._strikes[h] += 1
            else:
                self._strikes[h] = 0
            if self._strikes[h] >= self.cfg.evict_after:
                actions[h] = "replace"
            elif self._strikes[h] >= self.cfg.tolerate:
                actions[h] = "rebalance"
            else:
                actions[h] = "none"
        return actions

    def mitigate(self, actions: Dict[str, str]) -> Dict[str, Any]:
        """Make the policy's actions real through the wired hooks.

        "rebalance" shrinks the host's data shard via the
        :class:`BatchRebalancer` (which re-plans the host's local pipes at
        the shrunk shape); "replace" first drives the ``on_replace`` hook
        (the trainer's survivable_mesh + remesh_restore path) and then
        applies the bookkeeping eviction/spare promotion. Returns
        {host: outcome} for the non-"none" actions taken."""
        outcomes: Dict[str, Any] = {}
        for host, action in actions.items():
            if action == "rebalance" and self.rebalancer is not None:
                old_share = self.rebalancer.shares.get(host)
                new_share = self.rebalancer.shrink(host)
                if new_share != old_share:
                    # the shrunk shard gets a fresh chance; an already-
                    # floored share keeps its strikes so "replace" stays
                    # reachable when shrinking can no longer help
                    self._strikes[host] = 0
                outcomes[host] = {"action": "rebalance", "share": new_share}
            elif action == "replace":
                replaced = None
                if self.on_replace is not None:
                    replaced = self.on_replace(host)
                spare = self.replace(host)
                if self.rebalancer is not None:
                    self.rebalancer.drop(host)
                outcomes[host] = {"action": "replace", "spare": spare,
                                  "remesh": replaced}
            if host in outcomes:
                self.mitigations.append({"host": host, **outcomes[host]})
        return outcomes

    def step(self, host_times: Dict[str, float]) -> Dict[str, Any]:
        """observe + mitigate in one call (the trainer's per-step entry)."""
        return self.mitigate(self.observe_step(host_times))

    def replace(self, host: str) -> Optional[str]:
        """Evict ``host``; return the promoted spare (or None -> shrink)."""
        if host not in self.hosts:
            return None
        self.hosts.remove(host)
        self.evicted.append(host)
        self._times.pop(host, None)
        self._strikes.pop(host, None)
        if self.spares:
            spare = self.spares.pop(0)
            self.hosts.append(spare)
            self._times[spare] = deque(maxlen=self.cfg.window)
            self._strikes[spare] = 0
            return spare
        return None
